"""repro.api facade: EncodingSpec polymorphism + Accelerator/Executable.

The paper's claim — one accelerator, swappable neural encodings — as an
API contract:

* ``RadixEncoding`` runs on both backends and stays bit-exact against the
  oracle paths (the kernels sweep across T lives in
  tests/test_fused_epilogue.py).
* ``RateEncoding`` executes end-to-end through ``Accelerator.compile`` on
  the jnp backend, plan-vs-oracle exact — the first time rate coding is a
  runnable path rather than a dead helper.
* Invalid (backend, dataflow, encoding, net) pairings fail loudly at
  compile time; nothing silently falls through.
* ``Executable.stats()`` exposes the plan-cache counters across padding /
  top-bucket chunking / mixed streams (the PlanCache edge cases).
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import conversion
from repro.models import fang, lenet

RNG = np.random.default_rng(13)


def _make(maker=lenet, pool_mode="or", width_mult=0.25, **convert_kw):
    static, params, input_hw = maker.make(pool_mode=pool_mode,
                                          width_mult=width_mult)
    calib = jnp.asarray(RNG.uniform(0, 1, (4,) + input_hw), jnp.float32)
    qnet = conversion.convert(static, params, calib, **convert_kw)
    return qnet, input_hw


def _x(batch, input_hw):
    return jnp.asarray(RNG.uniform(0, 1, (batch,) + input_hw), jnp.float32)


# ---------------------------------------------------------------------------
# EncodingSpec declarations.
# ---------------------------------------------------------------------------


class TestEncodingSpecs:
    def test_radix_declarations(self):
        spec = api.RadixEncoding(4)
        assert spec.levels == 16 and spec.max_level == 15
        assert "kernels" in spec.backends and "jnp" in spec.backends
        assert spec.kernel_dataflows == ("fused", "bitserial")
        assert spec.validate_dataflow(None) == "fused"
        assert spec.supports_pool("or") and spec.supports_pool("max")

    def test_rate_declarations(self):
        spec = api.RateEncoding(7)
        assert spec.levels == 8 and spec.max_level == 7
        assert spec.backends == ("jnp",)
        assert spec.kernel_dataflows == ()
        with pytest.raises(ValueError, match="kernel dataflow"):
            spec.validate_dataflow("fused")
        assert spec.supports_pool("avg") and not spec.supports_pool("or")

    def test_specs_hashable_and_comparable(self):
        assert api.RadixEncoding(4) == api.RadixEncoding(4)
        assert api.RadixEncoding(4) != api.RadixEncoding(5)
        assert api.RadixEncoding(1) != api.RateEncoding(1)
        assert len({api.RadixEncoding(4), api.RadixEncoding(4),
                    api.RateEncoding(4)}) == 2

    def test_invalid_spec_params(self):
        with pytest.raises(ValueError, match="num_steps"):
            api.RadixEncoding(0)
        with pytest.raises(ValueError, match="scale"):
            api.RateEncoding(4, scale=0.0)

    def test_kernel_capable_specs_require_consistent_schedule(self):
        """Kernels capability is a per-spec KernelSchedule declaration;
        a subclass declaring dataflows with a schedule its own level
        algebra cannot ride (extraction bits too narrow for max_level,
        or an unknown epilogue grid) must be rejected instead of
        silently diverging from its requantize."""
        import dataclasses
        from typing import ClassVar, Tuple

        @dataclasses.dataclass(frozen=True)
        class NarrowSpec(api.RadixEncoding):
            """Declares one bit fewer than its levels need."""

            name: ClassVar[str] = "narrow"
            kernel_dataflows: ClassVar[Tuple[str, ...]] = ("fused",)

            def kernel_schedule(self):
                return dataclasses.replace(
                    super().kernel_schedule(),
                    packed_bits=self.num_steps - 1)

        with pytest.raises(ValueError, match="schedule is inconsistent"):
            NarrowSpec(4).validate_dataflow(None)
        from repro.kernels import ops
        with pytest.raises(ValueError, match="schedule is inconsistent"):
            ops._steps(NarrowSpec(4))

        @dataclasses.dataclass(frozen=True)
        class BadGridSpec(api.RadixEncoding):
            name: ClassVar[str] = "badgrid"

            def kernel_schedule(self):
                return dataclasses.replace(
                    super().kernel_schedule(), out_grid="fibonacci")

        with pytest.raises(ValueError, match="out_grid"):
            BadGridSpec(4).validate_dataflow(None)

    def test_kernel_schedule_declarations(self):
        """The shipped schedules: dense for radix/phase, pow2 for TTFS;
        jnp-only specs have none."""
        assert api.RadixEncoding(4).kernel_schedule() == api.KernelSchedule(
            packed_bits=4, periods=1, out_level=15, out_grid="dense")
        assert api.PhaseEncoding(8, periods=2).kernel_schedule() == \
            api.KernelSchedule(packed_bits=4, periods=2, out_level=15,
                               out_grid="dense")
        assert api.TTFSEncoding(4).kernel_schedule() == api.KernelSchedule(
            packed_bits=4, periods=1, out_level=15, out_grid="pow2")
        with pytest.raises(ValueError, match="kernel dataflow"):
            api.RateEncoding(4).kernel_schedule()

    def test_rate_integer_sigma_delta_exact(self):
        spec = api.RateEncoding(9)
        q = jnp.arange(10, dtype=jnp.int32)
        planes = spec.encode(q)
        assert planes.shape == (9, 10)
        np.testing.assert_array_equal(np.asarray(spec.decode(planes)),
                                      np.asarray(q))

    def test_convert_stores_spec(self):
        qnet, _ = _make(num_steps=4)
        assert qnet.encoding == api.RadixEncoding(4)
        assert qnet.spec == api.RadixEncoding(4)
        qnet, _ = _make(pool_mode="avg", encoding=api.RateEncoding(6))
        assert qnet.spec == api.RateEncoding(6)
        assert qnet.num_steps == 6

    def test_convert_validates_spec_args(self):
        static, params, input_hw = lenet.make(pool_mode="or",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (2,) + input_hw), jnp.float32)
        with pytest.raises(ValueError, match="num_steps"):
            conversion.convert(static, params, calib)
        with pytest.raises(ValueError, match="contradicts"):
            conversion.convert(static, params, calib, num_steps=3,
                               encoding=api.RadixEncoding(4))
        # rate + or-pool: the per-plane path does not commute -> loud error
        with pytest.raises(ValueError, match="pool mode"):
            conversion.convert(static, params, calib,
                               encoding=api.RateEncoding(6))


# ---------------------------------------------------------------------------
# RateEncoding end-to-end (the jnp backend).
# ---------------------------------------------------------------------------


class TestRateEndToEnd:
    @pytest.mark.parametrize("T", [3, 7])
    def test_rate_plan_vs_oracle(self, T):
        """Compiled (jitted, bucketed) rate executable == the spike-plane
        oracle == the packed twin, bit-exact, including pad + chunk."""
        qnet, hw = _make(pool_mode="avg", encoding=api.RateEncoding(T))
        exe = api.Accelerator(backend="jnp").compile(qnet, hw,
                                                     buckets=(1, 4))
        for n in (1, 3, 4, 9):
            x = _x(n, hw)
            want = api.oracle(qnet, x, mode="snn")
            np.testing.assert_array_equal(
                np.asarray(api.oracle(qnet, x, mode="packed")),
                np.asarray(want))
            np.testing.assert_array_equal(np.asarray(exe(x)),
                                          np.asarray(want))

    def test_rate_fang_cnn(self):
        qnet, hw = _make(fang, pool_mode="avg",
                         encoding=api.RateEncoding(5))
        exe = api.Accelerator(backend="jnp").compile(qnet, hw, buckets=(2,))
        x = _x(2, hw)
        np.testing.assert_array_equal(
            np.asarray(exe(x)), np.asarray(api.oracle(qnet, x, mode="snn")))

    def test_rate_scale_headroom_folds_into_conversion(self):
        """RateEncoding(scale=k): the headroom factor must reach the
        bias/multiplier/logit folding, not just quantize — regression for
        scale only being applied on the activation side (which mis-scaled
        biases 2x and zeroed every logit)."""
        static, params, input_hw = lenet.make(pool_mode="avg",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (16,) + input_hw),
                            jnp.float32)
        ref = np.asarray(
            conversion.float_forward(static, params, calib)).argmax(-1)
        spec = api.RateEncoding(31, scale=2.0)
        qnet = conversion.convert(static, params, calib, encoding=spec,
                                  weight_bits=8)
        assert qnet.input_scale == pytest.approx(2.0)   # calib max 1.0 * k
        out = api.oracle(qnet, calib, mode="packed")
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(api.oracle(qnet, calib,
                                                   mode="snn")))
        assert (np.asarray(out).argmax(-1) == ref).mean() >= 0.9

    def test_rate_needs_more_steps_than_radix(self):
        """The paper's motivating asymmetry, now measured on *executed*
        nets: radix T=4 (16 levels) beats rate T=4 (5 levels) at matching
        the float reference."""
        static, params, input_hw = lenet.make(pool_mode="avg",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (8,) + input_hw), jnp.float32)
        ref = conversion.float_forward(static, params, calib)
        errs = {}
        for name, spec in (("radix", api.RadixEncoding(4)),
                           ("rate", api.RateEncoding(4))):
            qnet = conversion.convert(static, params, calib, encoding=spec,
                                      weight_bits=8)
            out = api.oracle(qnet, calib, mode="packed")
            errs[name] = float(jnp.mean(jnp.abs(out - ref)))
        assert errs["radix"] < errs["rate"]


# ---------------------------------------------------------------------------
# Compile-time validation: no silent fall-throughs.
# ---------------------------------------------------------------------------


class TestCompileValidation:
    def test_backend_and_dataflow_args(self):
        with pytest.raises(ValueError, match="backend"):
            api.Accelerator(backend="xla")
        with pytest.raises(ValueError, match="kernels"):
            api.Accelerator(backend="jnp", dataflow="fused")

    def test_rate_on_kernels_backend_raises(self):
        qnet, hw = _make(pool_mode="avg", encoding=api.RateEncoding(6))
        with pytest.raises(ValueError, match="kernels"):
            api.Accelerator(backend="kernels").compile(qnet, hw)

    def test_unknown_dataflow_raises(self):
        qnet, hw = _make(num_steps=4)
        with pytest.raises(ValueError, match="dataflow"):
            api.Accelerator(dataflow="horner").compile(qnet, hw,
                                                       buckets=(1,))

    def test_mismatched_encoding_override_raises(self):
        qnet, hw = _make(num_steps=4)
        with pytest.raises(ValueError, match="reconvert"):
            api.Accelerator(backend="jnp").compile(
                qnet, hw, encoding=api.RateEncoding(4))
        with pytest.raises(ValueError, match="reconvert"):
            api.oracle(qnet, _x(1, hw), encoding=api.RadixEncoding(5))

    def test_parallel_requires_kernels(self):
        qnet, hw = _make(num_steps=4)
        with pytest.raises(ValueError, match="kernels"):
            api.Accelerator(backend="jnp").compile(qnet, hw, parallel=2)

    def test_oracle_mode_validation(self):
        qnet, hw = _make(num_steps=4)
        with pytest.raises(ValueError, match="mode"):
            api.oracle(qnet, _x(1, hw), mode="spiking")

    def test_item_shape_validation(self):
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(1,))
        with pytest.raises(ValueError, match="item shape"):
            exe(np.zeros((1, 8, 8, 1), np.float32))

    def test_facade_emits_no_warnings(self):
        """The supported surface is silent — deprecation noise belongs to
        the shims only (tests/test_api_shims.py)."""
        qnet, hw = _make(num_steps=4)
        x = _x(2, hw)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            api.oracle(qnet, x, mode="packed")
            api.oracle(qnet, x, mode="snn")
            exe = api.Accelerator().compile(qnet, hw, buckets=(2,))
            exe(x)
            api.Accelerator(dataflow="bitserial").compile(
                qnet, hw, buckets=(2,))(x)


# ---------------------------------------------------------------------------
# PlanCache chunking edge cases through Executable.stats() (DESIGN.md §3).
# ---------------------------------------------------------------------------


class TestExecutableStatsEdgeCases:
    def test_non_multiple_of_top_bucket(self):
        """Request sizes that are not a multiple of the top bucket: full
        top chunks plus one bucketed, padded tail — all counted."""
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(2, 4)).warmup()
        base = exe.stats()
        x = _x(10, hw)                      # 4 + 4 + tail 2 (bucket 2)
        ref = api.oracle(qnet, x, mode="packed")
        np.testing.assert_array_equal(np.asarray(exe(x)), np.asarray(ref))
        stats = exe.stats()
        assert stats["executions"] - base["executions"] == 3
        assert stats["padded_rows"] == base["padded_rows"]      # 2 fits 2
        assert stats["compiles"] == base["compiles"]
        x = _x(7, hw)                       # 4 + tail 3 -> pad to 4
        np.testing.assert_array_equal(
            np.asarray(exe(x)),
            np.asarray(api.oracle(qnet, x, mode="packed")))
        stats2 = exe.stats()
        assert stats2["executions"] - stats["executions"] == 2
        assert stats2["padded_rows"] - stats["padded_rows"] == 1
        assert stats2["compiles"] == stats["compiles"]

    def test_batch_of_exactly_one(self):
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(1, 4))
        x = _x(1, hw)
        ref = api.oracle(qnet, x, mode="packed")
        got = exe(x)
        assert got.shape[0] == 1
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        stats = exe.stats()
        assert stats["executions"] == 1 and stats["padded_rows"] == 0
        assert stats["compiles"] == 1                   # only bucket 1

    def test_mixed_stream_counters(self):
        """Stats across a mixed stream: hits + compiles add up, padding
        accumulates only on non-bucket sizes, zero steady-state
        recompiles."""
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(1, 4, 8))
        exe.warmup()
        warm = exe.stats()
        assert warm["compiles"] == 3
        sizes = (1, 3, 8, 2, 6, 13, 1, 7, 4)
        expected_execs = 0
        expected_pad = 0
        for n in sizes:
            chunks, rem = divmod(n, 8)
            if rem == 0:
                chunks, rem = chunks - 1, 8
            bucket = min(b for b in (1, 4, 8) if b >= rem)
            expected_execs += chunks + 1
            expected_pad += bucket - rem
            exe(_x(n, hw))
        stats = exe.stats()
        assert stats["compiles"] == warm["compiles"]    # zero recompiles
        assert (stats["executions"] - warm["executions"]) == expected_execs
        assert (stats["padded_rows"] - warm["padded_rows"]) == expected_pad
        assert stats["hits"] - warm["hits"] == expected_execs


# ---------------------------------------------------------------------------
# Introspection surface.
# ---------------------------------------------------------------------------


class TestIntrospection:
    def test_traffic_kernels_only(self):
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(1,))
        t = exe.traffic()
        assert t["traffic_ratio"] >= 3.0
        jexe = api.Accelerator(backend="jnp").compile(qnet, hw,
                                                      buckets=(1,))
        with pytest.raises(NotImplementedError, match="kernels"):
            jexe.traffic()

    def test_memory_report(self):
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(1,))
        rep = exe.memory()
        assert rep.total_buffer_bytes > 0
        assert rep.total_param_bytes > 0

    def test_repr_and_props(self):
        qnet, hw = _make(num_steps=4)
        exe = api.Accelerator().compile(qnet, hw, buckets=(4, 1))
        assert exe.buckets == (1, 4)
        assert exe.num_steps == 4
        assert "RadixEncoding" in repr(exe) and "kernels" in repr(exe)
