"""Sharding rules + distributed train/serve steps on a small mesh."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro import compat
from jax.sharding import PartitionSpec as P

from repro.configs import LM_ARCHS, get_config
from repro.lm import model as M
from repro.parallel import sharding as SH
from repro.parallel.zero import zero_upgrade
from repro.train import optim as optim_lib

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 placeholder devices")


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((2, 4), ("data", "model"))


def _axis_size(mesh, e):
    import numpy as _np
    if e is None:
        return 1
    if isinstance(e, tuple):
        return int(_np.prod([mesh.shape[a] for a in e]))
    return mesh.shape[e]


def _assert_valid(tree, specs, mesh):
    def check(leaf, spec):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d, e in zip(leaf.shape, entries):
            assert d % _axis_size(mesh, e) == 0, (leaf.shape, spec)
    jax.tree.map(check, tree, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_param_and_cache_specs_divide(arch, mesh):
    cfg = get_config(arch, smoke=True)
    aparams = M.abstract_params(cfg)
    _assert_valid(aparams, SH.param_specs(aparams, cfg, mesh), mesh)
    acache = M.abstract_cache(cfg, batch=8, max_len=32)
    _assert_valid(acache, SH.cache_specs(acache, cfg, mesh), mesh)


@pytest.mark.parametrize("arch", ["glm4_9b", "grok_1_314b"])
def test_sharded_train_matches_single_device(arch, mesh):
    """3 sharded training steps == 3 single-device steps (same math)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), seq_shard=True)
    if cfg.moe is not None:
        # generous capacity so distributed dispatch drops nothing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    opt = optim_lib.adafactor(1e-3)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0,
                                          cfg.vocab)}

    def run(mesh_or_none):
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        step = M.make_train_step(cfg, mesh_or_none, opt)
        if mesh_or_none is None:
            jstep = jax.jit(step)
            for _ in range(3):
                state, m = jstep(state, batch)
            return m["loss"]
        pspecs = SH.param_specs(jax.eval_shape(lambda: params), cfg, mesh)
        sspecs = {"params": pspecs,
                  "opt": SH.opt_state_specs(
                      pspecs, jax.eval_shape(lambda: state["opt"]), mesh),
                  "step": P()}
        with compat.set_mesh(mesh):
            st = jax.device_put(state, SH.shardings(sspecs, mesh))
            jstep = jax.jit(step, in_shardings=(SH.shardings(sspecs, mesh),
                                                SH.shardings(SH.batch_specs(
                                                    jax.eval_shape(lambda: batch),
                                                    cfg, mesh), mesh)),
                            out_shardings=(SH.shardings(sspecs, mesh), None))
            b = jax.device_put(batch, SH.shardings(SH.batch_specs(
                jax.eval_shape(lambda: batch), cfg, mesh), mesh))
            for _ in range(3):
                st, m = jstep(st, b)
            return m["loss"]

    l_single = float(run(None))
    l_mesh = float(run(mesh))
    # MoE ref (single-dev) vs capacity dispatch can differ slightly via
    # routing ties; dense archs must match tightly.
    tol = 5e-2 if cfg.moe is not None else 5e-4
    assert abs(l_single - l_mesh) <= tol * max(1.0, abs(l_single)), \
        (l_single, l_mesh)


def test_sharded_decode_matches_single_device(mesh):
    cfg = get_config("glm4_9b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (8, 9), 0, cfg.vocab)
    last_1, caches_1 = M.prefill(params, {"tokens": tok}, cfg, None,
                                 max_len=16)
    lg_1, _ = M.decode_step(params, caches_1, tok[:, -1:], jnp.int32(8),
                            cfg, None)
    with compat.set_mesh(mesh):
        last_m, caches_m = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, mesh, max_len=16))(
                params, {"tokens": tok})
        lg_m, _ = jax.jit(
            lambda p, c, t: M.decode_step(p, c, t, jnp.int32(8), cfg, mesh))(
                params, caches_m, tok[:, -1:])
    np.testing.assert_allclose(np.asarray(lg_1), np.asarray(lg_m),
                               rtol=2e-4, atol=2e-4)


def test_zero_upgrade_shards_replicated_leaves(mesh):
    specs = {"big": P(None, None), "tiny": P(None)}
    tree = {"big": jax.ShapeDtypeStruct((64, 32), jnp.float32),
            "tiny": jax.ShapeDtypeStruct((3,), jnp.float32)}
    up = zero_upgrade(specs, tree, mesh)
    assert up["big"] != specs["big"]          # got a data axis
    assert up["tiny"] == P(None)              # 3 % 2 != 0 -> untouched


def test_batch_specs_shard_batch_dim(mesh):
    cfg = get_config("qwen2_vl_72b", smoke=True)
    batch = {"embeds": jax.ShapeDtypeStruct((8, 16, cfg.d_model), jnp.float32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = SH.batch_specs(batch, cfg, mesh)
    assert specs["embeds"][0] is not None
    assert specs["labels"][0] is not None
