"""autoconfigure: lattice legality, constraints, provenance, surfaces.

Runs the planner once on the LeNet-5 smoke build (module fixture) and
probes the searched plan from every surface: the search result itself,
``plan.compile`` -> Executable, ``api.autoconfigure``,
``Accelerator.compile(auto=...)`` and the serve_cnn CLI validation.
"""

import numpy as np
import pytest

from repro import api
from repro.core import conversion
from repro.launch import serve_cnn
from repro.ppa import search

FLOOR, SLO = 0.6, 5000.0
KW = dict(accuracy_floor=FLOOR, latency_slo_us=SLO,
          t_range=(3, 4), units=(2, 4))


@pytest.fixture(scope="module")
def lenet_net():
    return serve_cnn.build_float_net("lenet5", smoke=True, pool_mode="avg",
                                     calib_batch=32, seed=0)


@pytest.fixture(scope="module")
def plan(lenet_net):
    static, params, item, calib = lenet_net
    return search.autoconfigure((static, params), item, calib=calib, **KW)


def test_winner_satisfies_constraints(plan):
    w = plan.winner
    assert w is not None and w.feasible
    assert w.accuracy >= FLOOR
    assert w.ppa.latency_us <= SLO
    assert w in plan.frontier
    assert len(plan.frontier) >= 1


def test_rejection_provenance_recorded(plan):
    rejected = [c for c in plan.candidates if not c.feasible]
    assert rejected, "smoke LeNet under a 0.6 floor must prune ttfs/T=3"
    # every rejection names its reason; accuracy prunes carry the value
    for c in rejected:
        assert c.rejected and all(r for r in c.rejected)
    assert any("accuracy" in r for c in rejected for r in c.rejected)


def test_accuracy_evaluated_once_per_spec(plan):
    legal_specs = {c.spec for c in plan.candidates if c.backend != "-"}
    assert plan.accuracy_evals == len(legal_specs)
    # all candidates of one spec share the accuracy number
    for spec in legal_specs:
        accs = {c.accuracy for c in plan.candidates if c.spec == spec}
        assert len(accs) == 1


def test_frontier_is_nondominated(plan):
    for c in plan.frontier:
        assert not any(search._dominates(o, c) for o in plan.frontier
                       if o is not c)


def test_objective_latency_picks_fastest(lenet_net):
    static, params, item, calib = lenet_net
    p = search.autoconfigure((static, params), item, calib=calib,
                             objective="latency", **KW)
    assert p.winner.ppa.latency_us == min(
        c.ppa.latency_us for c in p.frontier)


def test_summary_and_to_dict(plan):
    s = plan.summary()
    assert "winner:" in s and "rejected" in s and "constraints:" in s
    d = plan.to_dict()
    assert d["winner"]["accuracy"] == plan.winner.accuracy
    assert len(d["rejected"]) == sum(
        1 for c in plan.candidates if not c.feasible)
    assert d["n_candidates"] == len(plan.candidates)


def test_or_pooling_rejects_rate_and_ttfs_at_spec_level():
    static, params, item, calib = serve_cnn.build_float_net(
        "lenet5", smoke=True, pool_mode="or", calib_batch=8, seed=0)
    p = search.autoconfigure((static, params), item, calib=calib,
                             accuracy_floor=0.01, t_range=(3,), units=(2,))
    spec_level = {c.spec.name: c for c in p.candidates if c.backend == "-"}
    assert {"rate", "ttfs"} <= set(spec_level)
    for c in spec_level.values():
        assert c.units == 0 and c.ppa is None
        assert any("illegal for this net" in r for r in c.rejected)
    # radix still wins on the or-pool net
    assert p.winner is not None and p.winner.spec.name == "radix"


def test_infeasible_floor_yields_no_winner(lenet_net):
    static, params, item, calib = lenet_net
    p = search.autoconfigure((static, params), item, calib=calib,
                             accuracy_floor=2.0, t_range=(3,), units=(2,))
    assert p.winner is None and p.frontier == []
    assert all(not c.feasible for c in p.candidates)
    with pytest.raises(ValueError, match="no feasible configuration"):
        p.compile()


def test_input_validation(lenet_net):
    static, params, item, calib = lenet_net
    qnet = conversion.convert(static, params, calib, num_steps=4)
    with pytest.raises(TypeError, match="QuantizedNet"):
        search.autoconfigure(qnet, item, calib=calib, accuracy_floor=0.5)
    with pytest.raises(TypeError, match="pair"):
        search.autoconfigure(42, item, calib=calib, accuracy_floor=0.5)
    with pytest.raises(ValueError, match="objective"):
        search.autoconfigure((static, params), item, calib=calib,
                             accuracy_floor=0.5, objective="area")
    with pytest.raises(ValueError, match="non-empty"):
        search.autoconfigure((static, params), item, calib=calib,
                             accuracy_floor=0.5, t_range=())
    with pytest.raises(ValueError, match="calib item shape"):
        search.autoconfigure((static, params), (8, 8, 3), calib=calib,
                             accuracy_floor=0.5)


def test_plan_compile_round_trip(plan, lenet_net):
    _, _, item, calib = lenet_net
    exe = plan.compile(buckets=(4,))
    assert exe.encoding == plan.winner.spec
    assert exe.backend == plan.winner.backend
    out = np.asarray(exe(calib[:4]))
    assert out.shape == (4, 10)
    ppa = exe.stats()["ppa"]
    assert ppa["latency_us"] == pytest.approx(plan.winner.ppa.latency_us)
    assert ppa["energy_uj"] == pytest.approx(plan.winner.ppa.energy_uj)


def test_api_facade_matches_search(lenet_net):
    static, params, item, calib = lenet_net
    p = api.autoconfigure((static, params), item, calib=calib,
                          accuracy_floor=0.5, t_range=(3,), units=(2,))
    assert p.winner is not None
    assert isinstance(p, search.AutoPlan)


def test_accelerator_compile_auto(lenet_net):
    static, params, item, calib = lenet_net
    exe = api.Accelerator().compile(
        (static, params), item,
        auto=dict(calib=calib, accuracy_floor=0.5, t_range=(3,),
                  units=(2,)), buckets=(2,))
    assert exe.auto_plan.winner is not None
    assert exe.encoding == exe.auto_plan.winner.spec
    out = np.asarray(exe(calib[:2]))
    assert out.shape == (2, 10)


def test_accelerator_compile_auto_conflicts(lenet_net):
    static, params, item, calib = lenet_net
    auto = dict(calib=calib, accuracy_floor=0.5)
    with pytest.raises(ValueError, match="dataflow"):
        api.Accelerator(dataflow="fused").compile((static, params), item,
                                                  auto=auto)
    with pytest.raises(ValueError, match="encoding"):
        api.Accelerator().compile((static, params), item, auto=auto,
                                  encoding=api.RadixEncoding(4))


# ---------------------------------------------------------------------------
# serve_cnn CLI validation (the planner flags)
# ---------------------------------------------------------------------------


def _parse(extra):
    return serve_cnn._parse_args(["--arch", "lenet5", "--smoke"] + extra)


def test_cli_auto_defaults():
    args = _parse(["--auto"])
    assert args.auto and args.accuracy_floor == 0.9
    assert args.latency_slo is None and args.energy_budget is None


def test_cli_auto_owns_the_planner_axes(capsys):
    for flag in (["--encoding", "ttfs"], ["--num-steps", "4"],
                 ["--dataflow", "fused"], ["--backend", "jnp"],
                 ["--periods", "2"]):
        with pytest.raises(SystemExit):
            _parse(["--auto"] + flag)
        assert "conflicts with --auto" in capsys.readouterr().err


def test_cli_constraints_require_auto(capsys):
    for flag in (["--accuracy-floor", "0.9"], ["--latency-slo", "100"],
                 ["--energy-budget", "50"]):
        with pytest.raises(SystemExit):
            _parse(flag)
        assert "requires --auto" in capsys.readouterr().err


def test_cli_constraint_ranges(capsys):
    for flag in (["--accuracy-floor", "1.5"], ["--accuracy-floor", "0"],
                 ["--latency-slo", "-1"], ["--energy-budget", "0"]):
        with pytest.raises(SystemExit):
            _parse(["--auto"] + flag)
    args = _parse(["--auto", "--accuracy-floor", "0.7",
                   "--latency-slo", "800", "--energy-budget", "2500"])
    assert (args.accuracy_floor, args.latency_slo,
            args.energy_budget) == (0.7, 800.0, 2500.0)
