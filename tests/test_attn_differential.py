"""Differential suite for packed-plane decode attention (ISSUE 10).

Locks ``kernels/radix_attn.py`` (and its ops.py wrapper + LM wiring) with
four independent reference points:

1. **Plane-level oracle** (``ref.decode_attn_ref``): the kernel equals a
   second, independently-spelled derivation of the plane-weight algebra
   to f32 rounding, across (T, GQA group, batch, cache fill, pack
   on/off, bitserial/fused, xla/pallas) — fixed-seed fast subset plus a
   ``_hyp`` fuzz sweep.
2. **Float jnp path**: the packed kernel stays within a *derived*
   dequant-error bound of the exact softmax over the dequantized cache —
   the only approximation is the on-the-fly Q_BITS query quantization,
   whose worst-case score perturbation eps gives the closed-form bound
   ``(e^(2 eps) - 1) * max(v_scale)`` via softmax Lipschitz continuity.
3. **Masked-score set**: the mask the packed branch consumes is the very
   ``blocks.decode_mask`` array the jnp branch applies, pinned against a
   write-replay simulation oracle (``ref.decode_mask_ref``), ring-buffer
   wraparound included; garbage in masked cache slots cannot leak.
4. **Online-softmax core properties**: block-split invariance, all-
   masked stability (no NaN from -1e30 rows), scale-fold associativity.

Plus the e2e long-decode regression: 64 greedy tokens through
``LMExecutable`` with ``packed_attn`` on vs off.
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import api
from repro.configs import get_config
from repro.kernels import ops as kops, ref
from repro.kernels import radix_attn as ra
from repro.lm import blocks, model as M

pytestmark = pytest.mark.lm


# ---------------------------------------------------------------------------
# Shared fixtures: synthetic radix caches and the float reference path.
# ---------------------------------------------------------------------------


def _mk_problem(seed, B, S, hkv, g, hd, T, fill=1.0):
    """Random decode-attention problem: float q + a radix cache whose
    first ceil(fill * S) slots are valid."""
    rng = np.random.default_rng(seed)
    lvl = (1 << T) - 1
    q = jnp.asarray(rng.normal(size=(B, hkv * g, hd)).astype(np.float32))
    k_q = rng.integers(0, lvl + 1, size=(B, S, hkv, hd)).astype(np.uint8)
    v_q = rng.integers(0, lvl + 1, size=(B, S, hkv, hd)).astype(np.uint8)
    k_s = rng.uniform(0.25, 2.0, size=(B, S, hkv)).astype(np.float32)
    v_s = rng.uniform(0.25, 2.0, size=(B, S, hkv)).astype(np.float32)
    n_valid = max(1, int(round(fill * S)))
    mask = np.zeros((B, S), bool)
    mask[:, :n_valid] = True
    return q, k_q, k_s, v_q, v_s, mask


def _pack4(lv):
    return ((lv[..., 0::2] << 4) | lv[..., 1::2]).astype(np.uint8)


def _dequant(lv, s, T):
    lvl = (1 << T) - 1
    return (2.0 * lv.astype(np.float32) / lvl - 1.0) * s[..., None]


def _float_path(q, k_q, k_s, v_q, v_s, mask, T):
    """The jnp decode-attention math (dequantize + masked softmax) with
    the FLOAT query — what blocks.decode_attention computes when
    ``packed_attn`` is off.  (B, H, hd) f32."""
    B, H, hd = q.shape
    hkv = k_q.shape[2]
    g = H // hkv
    k = _dequant(k_q, k_s, T)
    v = _dequant(v_q, v_s, T)
    qg = np.asarray(q, np.float32).reshape(B, hkv, g, hd)
    s = np.einsum("bhgd,bshd->bhgs", qg, k) * hd ** -0.5
    s = np.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(jnp.asarray(s), axis=-1)
    out = np.einsum("bhgs,bshd->bhgd", np.asarray(p), v)
    return out.reshape(B, H, hd)


def _run_kernel(q, k_q, k_s, v_q, v_s, mask, T, *, packed=False,
                method="bitserial", impl="xla", bk=None, **kw):
    if packed:
        k_q, v_q = _pack4(k_q), _pack4(v_q)
    cfgk = kops.KernelConfig(impl=impl, **({} if bk is None else {"bk": bk}))
    return kops.radix_decode_attention(
        q, jnp.asarray(k_q), jnp.asarray(k_s), jnp.asarray(v_q),
        jnp.asarray(v_s), jnp.asarray(mask), T, packed=packed,
        method=method, config=cfgk, **kw)


def _dequant_bound(q, k_s, v_s, hd, mask):
    """Worst-case packed-vs-float output error from Q_BITS quantization.

    Per-element query error <= qs / qlvl, k-hat elements <= sk, so every
    score moves by at most eps = sqrt(hd)'s worst case
    hd^-0.5 * hd * qs * sk / qlvl = sqrt(hd) * max(qs * sk) / qlvl.
    Softmax is Lipschitz in the scores: ||p' - p||_1 <= e^(2 eps) - 1,
    and each value element is bounded by max(sv), giving the bound used
    here (a 1.5x float-rounding cushion on top)."""
    qlvl = (1 << ra.Q_BITS) - 1
    qs = np.abs(np.asarray(q)).max(-1)                     # (B, H)
    sk = np.where(mask[:, :, None], np.asarray(k_s), 0.0).max(1)  # (B, Hkv)
    sv = np.where(mask[:, :, None], np.asarray(v_s), 0.0).max()
    eps = np.sqrt(hd) * qs.max() * sk.max() / qlvl
    return 1.5 * (np.expm1(2.0 * eps)) * sv + 1e-5


# ---------------------------------------------------------------------------
# 1. kernel == plane-level oracle (fixed-seed fast subset + fuzz sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("method", ["bitserial", "fused"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_kernel_matches_oracle_fixed(packed, method, impl):
    T = 4
    q, k_q, k_s, v_q, v_s, mask = _mk_problem(0, 2, 16, 2, 2, 8, T, 0.7)
    want = ref.decode_attn_ref(q, jnp.asarray(k_q), jnp.asarray(k_s),
                               jnp.asarray(v_q), jnp.asarray(v_s),
                               jnp.asarray(mask), T)
    got = _run_kernel(q, k_q, k_s, v_q, v_s, mask, T,
                      packed=packed, method=method, impl=impl, bk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(T=st.integers(2, 8), g=st.integers(1, 4), hkv=st.integers(1, 3),
       B=st.integers(1, 3), fill=st.floats(0.1, 1.0),
       pack=st.booleans(), fused=st.booleans(), seed=st.integers(0, 2**16))
def test_kernel_matches_oracle_fuzz(T, g, hkv, B, fill, pack, fused, seed):
    """The full ISSUE-10 sweep axis set: (T, GQA group size, batch,
    cache fill, pack on/off) x dataflow, against the plane oracle."""
    pack = pack and T <= 4                 # nibble packing needs T <= 4
    S, hd = 16, 8
    q, k_q, k_s, v_q, v_s, mask = _mk_problem(seed, B, S, hkv, g, hd, T,
                                              fill)
    want = ref.decode_attn_ref(q, jnp.asarray(k_q), jnp.asarray(k_s),
                               jnp.asarray(v_q), jnp.asarray(v_s),
                               jnp.asarray(mask), T)
    got = _run_kernel(q, k_q, k_s, v_q, v_s, mask, T, packed=pack,
                      method="fused" if fused else "bitserial")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_strategies_agree_to_f32_rounding():
    """Every legal KernelConfig (block sizes, lowerings, xla/pallas,
    sparsity on/off) computes the same attention to f32 rounding — the
    attention analogue of the matmul suite's bit-equality lock (the
    integer dots ARE bit-exact; the float softmax reassociates across
    KV-block partitions, so the contract here is a tight float tol)."""
    T = 4
    q, k_q, k_s, v_q, v_s, mask = _mk_problem(3, 2, 24, 2, 2, 8, T, 0.8)
    base = _run_kernel(q, k_q, k_s, v_q, v_s, mask, T)
    for kw in ({"bk": 8}, {"bk": 24}, {"impl": "pallas", "bk": 8},
               {"method": "fused"}, {"sparsity": False},
               {"packed": True}, {"packed": True, "impl": "pallas",
                                  "bk": 8}):
        got = _run_kernel(q, k_q, k_s, v_q, v_s, mask, T,
                          **{"impl": "xla", **kw})
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=2e-5, atol=2e-5, err_msg=repr(kw))


# ---------------------------------------------------------------------------
# 2. packed kernel vs the float jnp path: derived dequant-error bound
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(T=st.integers(3, 8), seed=st.integers(0, 2**16),
       pack=st.booleans())
def test_kernel_within_derived_bound_of_float_path(T, seed, pack):
    pack = pack and T <= 4
    B, S, hkv, g, hd = 2, 16, 2, 2, 8
    q, k_q, k_s, v_q, v_s, mask = _mk_problem(seed, B, S, hkv, g, hd, T,
                                              0.75)
    want = _float_path(q, k_q, k_s, v_q, v_s, mask, T)
    got = np.asarray(_run_kernel(q, k_q, k_s, v_q, v_s, mask, T,
                                 packed=pack))
    bound = _dequant_bound(q, k_s, v_s, hd, mask)
    err = np.abs(got - want).max()
    assert err <= bound, (err, bound)


# ---------------------------------------------------------------------------
# 3. the masked-score set is EXACTLY the jnp path's, both mask shapes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(pos=st.integers(0, 100), s_len=st.integers(1, 40),
       windowed=st.booleans())
def test_decode_mask_equals_simulation_oracle(pos, s_len, windowed):
    """blocks.decode_mask (the one array BOTH the jnp softmax and the
    packed kernel consume) == replaying every ring-buffer write —
    wraparound included (pos >> window exercises it)."""
    window = s_len if windowed else 0
    if not windowed:
        pos = min(pos, s_len - 1)          # full attn: cache never wraps
    got = blocks.decode_mask(jnp.int32(pos), s_len, window)[0]
    want = ref.decode_mask_ref(pos, s_len, window)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_masked_slots_cannot_leak():
    """Adversarial garbage (max levels, huge scales) in masked cache
    slots changes NOTHING in either the packed kernel or the float
    path — the observational form of masked-score-set equality."""
    T = 4
    q, k_q, k_s, v_q, v_s, mask = _mk_problem(5, 2, 16, 2, 2, 8, T, 0.5)
    dead = ~mask
    k_g, v_g = k_q.copy(), v_q.copy()
    k_sg, v_sg = k_s.copy(), v_s.copy()
    k_g[dead] = 15
    v_g[dead] = 15
    k_sg[dead] = 1e6
    v_sg[dead] = 1e6
    for kw in ({}, {"packed": True}, {"impl": "pallas", "bk": 8}):
        a = _run_kernel(q, k_q, k_s, v_q, v_s, mask, T, **kw)
        b = _run_kernel(q, k_g, k_sg, v_g, v_sg, mask, T, **kw)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=repr(kw))
    np.testing.assert_array_equal(
        _float_path(q, k_q, k_s, v_q, v_s, mask, T),
        _float_path(q, k_g, k_sg, v_g, v_sg, mask, T))


def test_windowed_ring_mask_matches_jnp_semantics():
    """Sliding-window decode: the packed kernel over the ring-buffer
    mask equals the float path over the same mask (softmax over ring
    slots is permutation-invariant, so no unrotation is needed)."""
    T, B, S, hkv, g, hd = 4, 2, 8, 2, 2, 8
    window = S
    for pos in (3, 7, 11, 29):             # before and after wraparound
        q, k_q, k_s, v_q, v_s, _ = _mk_problem(pos, B, S, hkv, g, hd, T)
        mask = np.asarray(
            np.broadcast_to(ref.decode_mask_ref(pos, S, window), (B, S)))
        got = np.asarray(_run_kernel(q, k_q, k_s, v_q, v_s, mask, T,
                                     packed=True))
        want = _float_path(q, k_q, k_s, v_q, v_s, mask, T)
        bound = _dequant_bound(q, k_s, v_s, hd, mask)
        assert np.abs(got - want).max() <= bound


# ---------------------------------------------------------------------------
# 4. online-softmax core properties
# ---------------------------------------------------------------------------


def _osm_sweep(scores, mask, v, splits):
    """Run the streaming core over a block partition of the S axis."""
    g, hd = scores.shape[0], v.shape[1]
    state = ra.osm_init((g, 1), (g, hd))
    for lo, hi in splits:
        state = ra.osm_update(
            state, scores[:, lo:hi], mask[:, lo:hi],
            lambda p, lo=lo, hi=hi: p @ v[lo:hi])
    return ra.osm_finalize(state)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), split=st.integers(1, 15),
       fill=st.floats(0.0, 1.0))
def test_osm_block_split_invariance(seed, split, fill):
    """Any block partition == the single-pass softmax within 1e-6 —
    including rows whose valid slots all land in one block."""
    rng = np.random.default_rng(seed)
    g, S, hd = 3, 16, 4
    scores = jnp.asarray(rng.normal(size=(g, S)).astype(np.float32) * 5)
    mask = jnp.asarray(rng.random((g, S)) < fill)
    v = jnp.asarray(rng.normal(size=(S, hd)).astype(np.float32))
    one = _osm_sweep(scores, mask, v, [(0, S)])
    cut = split if split < S else S - 1
    two = _osm_sweep(scores, mask, v, [(0, cut), (cut, S)])
    man = _osm_sweep(scores, mask, v, [(i, i + 1) for i in range(S)])
    np.testing.assert_allclose(np.asarray(two), np.asarray(one), atol=1e-6)
    np.testing.assert_allclose(np.asarray(man), np.asarray(one), atol=1e-6)


def test_osm_all_masked_blocks_are_stable():
    """Fully-masked rows (and all-masked leading blocks) produce exact
    zeros — never NaN from exp(-1e30 - -1e30) or 0/0."""
    g, S, hd = 2, 12, 4
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(g, S)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(S, hd)).astype(np.float32))
    out = _osm_sweep(scores, jnp.zeros((g, S), bool), v,
                     [(0, 4), (4, 8), (8, 12)])
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    # row 0 masked, row 1 valid only in the LAST block: earlier all-
    # masked updates must not poison the running max / sum
    mask = np.zeros((g, S), bool)
    mask[1, 9] = True
    out = _osm_sweep(scores, jnp.asarray(mask), v,
                     [(0, 4), (4, 8), (8, 12)])
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_array_equal(np.asarray(out)[0], 0.0)
    np.testing.assert_allclose(np.asarray(out)[1], np.asarray(v)[9],
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_osm_scale_fold_associativity(seed):
    """Folding the per-token v-scales into p before the value dot
    (what the kernel streams) == scaling the dequantized values first
    (what the float path does): (p * sv) @ V == p @ (sv[:, None] * V)."""
    rng = np.random.default_rng(seed)
    g, S, hd = 2, 16, 4
    scores = jnp.asarray(rng.normal(size=(g, S)).astype(np.float32))
    mask = jnp.asarray(rng.random((g, S)) < 0.8)
    sv = jnp.asarray(rng.uniform(0.25, 4.0, size=(S,)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(S, hd)).astype(np.float32))
    a = _osm_sweep(scores, mask, sv[:, None] * v, [(0, 8), (8, S)])
    g2, hd2 = scores.shape[0], v.shape[1]
    state = ra.osm_init((g2, 1), (g2, hd2))
    for lo, hi in [(0, 8), (8, S)]:
        state = ra.osm_update(
            state, scores[:, lo:hi], mask[:, lo:hi],
            lambda p, lo=lo, hi=hi: (p * sv[lo:hi]) @ v[lo:hi])
    b = ra.osm_finalize(state)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# 5. e2e long-decode regression: packed_attn on vs off through the
#    compiled serving surface
# ---------------------------------------------------------------------------


def test_long_decode_packed_vs_float_regression():
    """64 greedy tokens through LMExecutable with packed_attn on vs off:
    argmax-token agreement above the BENCH_lm agreement floor, per-step
    logit rel-err under the committed BENCH_lm T=4 accuracy floor, and
    zero steady-state recompiles on both plans."""
    bench = json.loads((pathlib.Path(__file__).resolve().parents[1]
                        / "BENCH_lm.json").read_text())
    floor = next(r["logit_rel_err"] for r in bench["accuracy"]
                 if r["T"] == 4)
    new_tokens = 64
    cfg = dataclasses.replace(get_config("gemma_2b", smoke=True),
                              radix_steps=4, radix_kv_pack=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0, cfg.vocab)
    logits, toks = {}, {}
    for packed in (False, True):
        c = dataclasses.replace(cfg, packed_attn=packed)
        exe = api.Accelerator(backend="jnp").compile(
            (params, c), (2, 8 + new_tokens + 2), buckets=(8,))
        exe.warmup()
        compiles0 = exe.stats()["compiles"]
        state = exe.prefill(tok)
        steps, out = [], []
        for _ in range(new_tokens):
            nxt = jnp.argmax(state["logits"], -1).astype(jnp.int32)
            out.append(np.asarray(nxt))
            state = exe.decode(state, nxt[:, None])
            steps.append(np.asarray(state["logits"]))
        assert exe.stats()["compiles"] == compiles0   # zero steady-state
        logits[packed] = np.stack(steps, 1)           # (B, 64, vocab)
        toks[packed] = np.stack(out, 1)
    agree = float((toks[True] == toks[False]).mean())
    assert agree >= 0.75, agree                       # REPRO_LM_AGREE_FLOOR
    rel = (np.linalg.norm(logits[True] - logits[False], axis=-1)
           / np.linalg.norm(logits[False], axis=-1))
    assert float(np.median(rel)) < floor, (float(np.median(rel)), floor)
