"""The block-size/strategy autotuner (repro.kernels.autotune).

Covers the machinery the kernels build on: the winner cache (hit/miss/
disk counters, key anatomy — a key that dropped the dataflow or encoding
schedule would alias distinct problems), deterministic winner selection
under an injectable timer, the exactness gate for non-default MXU dot
lowerings, and the end-to-end ops.radix_matmul(autotune=True) path.
"""

import json

import numpy as np
import pytest

from repro.core import encoding
from repro.kernels import autotune as at
from repro.kernels.autotune import (
    AutotuneCache, KernelConfig, conv_key, exact_lowering,
    matmul_candidates, matmul_key, tune,
)


def _sched(T=4, periods=1, out_grid="dense"):
    return encoding.KernelSchedule(packed_bits=T, periods=periods,
                                   out_grid=out_grid)


# ---------------------------------------------------------------------------
# KernelConfig + exactness gate.
# ---------------------------------------------------------------------------


class TestKernelConfig:
    def test_roundtrip(self):
        cfg = KernelConfig(impl="xla", mxu_dtype="f32", bm=64,
                           plane_parallel=True)
        assert KernelConfig.from_dict(cfg.as_dict()) == cfg

    def test_validates(self):
        with pytest.raises(ValueError):
            KernelConfig(impl="cuda")
        with pytest.raises(ValueError):
            KernelConfig(mxu_dtype="int4")

    def test_default_is_untuned_heuristic(self):
        """The first candidate everywhere: today's 128-tile int32 path."""
        cfg = KernelConfig()
        assert (cfg.impl, cfg.mxu_dtype) == ("pallas", "int32")
        assert (cfg.bm, cfg.bk, cfg.bn, cfg.bco) == (128, 128, 128, 128)
        assert not cfg.plane_parallel


class TestExactLowering:
    def test_int32_always_exact(self):
        assert exact_lowering("int32", max_operand=255, k_contract=1 << 20,
                              method="fused")

    def test_int8_operand_bound(self):
        """int8 inputs hold values <= 127: bit planes always fit, packed
        fused operands only while the level fits 7 bits (T <= 7)."""
        assert exact_lowering("int8", max_operand=1, k_contract=4096,
                              method="bitserial")
        assert exact_lowering("int8", max_operand=127, k_contract=4096,
                              method="fused")
        assert not exact_lowering("int8", max_operand=255, k_contract=64,
                                  method="fused")

    def test_f32_partial_sum_bound(self):
        """f32 accumulates exactly below 2^24; the guard keeps the worst
        per-k-tile partial sum under half of that."""
        assert exact_lowering("f32", max_operand=15, k_contract=128,
                              method="fused")
        assert not exact_lowering("f32", max_operand=255,
                                  k_contract=1 << 16, method="fused")


# ---------------------------------------------------------------------------
# Key anatomy: every schedule/dataflow axis must separate keys.
# ---------------------------------------------------------------------------


class TestKeys:
    def test_dataflow_separates(self):
        a = matmul_key(8, 16, 8, _sched(), "fused", epilogue=False,
                       sparsity=False, backend="cpu")
        b = matmul_key(8, 16, 8, _sched(), "bitserial", epilogue=False,
                       sparsity=False, backend="cpu")
        assert a != b

    def test_schedule_separates(self):
        """radix T=4 vs phase T=4/P=2 pack identical bytes but replay
        different plane schedules — one winner must not serve both."""
        kw = dict(epilogue=False, sparsity=False, backend="cpu")
        radix = matmul_key(8, 16, 8, _sched(T=4), "bitserial", **kw)
        phase = matmul_key(8, 16, 8, _sched(T=4, periods=2), "bitserial",
                           **kw)
        assert radix != phase

    def test_out_grid_separates_only_with_epilogue(self):
        kw = dict(sparsity=False, backend="cpu")
        dense = matmul_key(8, 16, 8, _sched(out_grid="dense"), "fused",
                           epilogue=True, **kw)
        pow2 = matmul_key(8, 16, 8, _sched(out_grid="pow2"), "fused",
                          epilogue=True, **kw)
        assert dense != pow2
        # raw accumulators never run the projection -> grid folds away
        raw_a = matmul_key(8, 16, 8, _sched(out_grid="dense"), "fused",
                           epilogue=False, **kw)
        raw_b = matmul_key(8, 16, 8, _sched(out_grid="pow2"), "fused",
                           epilogue=False, **kw)
        assert raw_a == raw_b

    def test_epilogue_sparsity_shape_separate(self):
        base = dict(epilogue=False, sparsity=False, backend="cpu")
        k0 = matmul_key(8, 16, 8, _sched(), "fused", **base)
        assert k0 != matmul_key(8, 16, 8, _sched(), "fused",
                                epilogue=True, sparsity=False, backend="cpu")
        assert k0 != matmul_key(8, 16, 8, _sched(), "fused",
                                epilogue=False, sparsity=True, backend="cpu")
        assert k0 != matmul_key(16, 16, 8, _sched(), "fused", **base)

    def test_conv_key_includes_geometry(self):
        kw = dict(batch=2, epilogue=False, sparsity=False, backend="cpu")
        a = conv_key(8, 8, 3, 3, 3, 16, 1, _sched(), "fused", **kw)
        b = conv_key(8, 8, 3, 3, 3, 16, 2, _sched(), "fused", **kw)
        assert a != b                     # stride
        c = conv_key(8, 8, 3, 5, 5, 16, 1, _sched(), "fused", **kw)
        assert a != c                     # kernel size

    def test_forced_collision_is_the_same_problem(self):
        """Identical problems DO collide — that's the cache working."""
        a = matmul_key(8, 16, 8, _sched(), "fused", epilogue=True,
                       sparsity=True, backend="cpu")
        b = matmul_key(8, 16, 8, _sched(T=4), "fused", epilogue=True,
                       sparsity=True, backend="cpu")
        assert a == b


# ---------------------------------------------------------------------------
# Candidates.
# ---------------------------------------------------------------------------


class TestCandidates:
    def test_first_candidate_is_the_default(self):
        """An interrupted sweep can never regress below the untuned path:
        position 0 is always KernelConfig() (ties break by order)."""
        for method in ("fused", "bitserial"):
            cands = matmul_candidates(128, 256, 128, _sched(), method,
                                      interpret=False)
            assert cands[0] == KernelConfig()

    def test_bitserial_sweeps_plane_parallel_fused_does_not(self):
        fused = matmul_candidates(128, 256, 128, _sched(), "fused",
                                  interpret=False)
        bits = matmul_candidates(128, 256, 128, _sched(), "bitserial",
                                 interpret=False)
        assert not any(c.plane_parallel for c in fused)
        assert any(c.plane_parallel for c in bits)

    def test_only_exact_lowerings_offered(self):
        """T=8 packed fused operands overflow int8 -> no int8 candidate."""
        cands = matmul_candidates(64, 64, 64, _sched(T=8), "fused",
                                  interpret=False)
        assert not any(c.mxu_dtype == "int8" for c in cands)
        cands4 = matmul_candidates(64, 64, 64, _sched(T=4), "fused",
                                   interpret=False)
        assert any(c.mxu_dtype == "int8" for c in cands4)

    def test_no_duplicates(self):
        cands = matmul_candidates(8, 16, 8, _sched(), "bitserial",
                                  interpret=True)
        assert len(cands) == len(set(cands))

    def test_f32_act_only_on_fused_xla_twin(self):
        """act_dtype='f32' is an XLA-fused-only layout: bit-serial plane
        extraction needs the packed bytes, and the Pallas programs take
        the packed layout by contract."""
        fused = matmul_candidates(128, 256, 128, _sched(), "fused",
                                  interpret=False)
        f32_act = [c for c in fused if c.act_dtype == "f32"]
        assert f32_act and all(c.impl == "xla" for c in f32_act)
        bits = matmul_candidates(128, 256, 128, _sched(), "bitserial",
                                 interpret=False)
        assert not any(c.act_dtype == "f32" for c in bits)

    def test_plan_sweep_excludes_f32_act(self):
        """Compiled plans pass act_dtypes=("u8",): their inter-layer
        contract ships packed uint8 activations."""
        cands = matmul_candidates(128, 256, 128, _sched(), "fused",
                                  interpret=False, act_dtypes=("u8",))
        assert not any(c.act_dtype == "f32" for c in cands)

    def test_f32_act_requires_exact_f32_lowering(self):
        """No f32-layout candidate when the partial sum can escape the
        24-bit mantissa (the same gate as mxu_dtype='f32')."""
        cands = matmul_candidates(64, 1 << 16, 64, _sched(T=8), "fused",
                                  interpret=False)
        assert not any(c.act_dtype == "f32" for c in cands)

    def test_act_dtype_validates(self):
        with pytest.raises(ValueError):
            KernelConfig(act_dtype="bf16")


# ---------------------------------------------------------------------------
# Cache counters + disk round-trip.
# ---------------------------------------------------------------------------


class TestCache:
    def test_hit_miss_counters(self):
        cache = AutotuneCache(None)
        key = ("matmul", "cpu", 1)
        assert cache.get(key) is None
        assert cache.stats.misses == 1
        cache.put(key, KernelConfig(impl="xla"), 12.5)
        assert cache.get(key) == KernelConfig(impl="xla")
        assert cache.stats.hits == 1
        assert cache.stats.disk_hits == 0

    def test_disk_roundtrip(self, tmp_path):
        path = tmp_path / "autotune.json"
        a = AutotuneCache(path)
        key = matmul_key(8, 16, 8, _sched(), "fused", epilogue=False,
                         sparsity=False, backend="cpu")
        a.put(key, KernelConfig(impl="xla", mxu_dtype="f32"), 3.0)
        # a second process: fresh cache object, same file
        b = AutotuneCache(path)
        assert b.get(key) == KernelConfig(impl="xla", mxu_dtype="f32")
        assert b.stats.disk_hits == 1 and b.stats.hits == 1
        # the payload is versioned JSON, inspectable by humans
        payload = json.loads(path.read_text())
        assert payload["version"] == 1 and len(payload["entries"]) == 1

    def test_corrupt_disk_table_is_cold_cache(self, tmp_path):
        path = tmp_path / "autotune.json"
        path.write_text("{not json")
        cache = AutotuneCache(path)
        key = ("matmul", "cpu", 2)
        assert cache.get(key) is None          # no raise
        cache.put(key, KernelConfig(), 1.0)    # and the file heals
        assert json.loads(path.read_text())["version"] == 1

    def test_env_var_disables_disk(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
        assert at.cache_path() is None
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "t.json"))
        assert at.cache_path() == tmp_path / "t.json"


# ---------------------------------------------------------------------------
# The tuning loop: injectable timer, deterministic winner.
# ---------------------------------------------------------------------------


class TestTune:
    def _candidates(self):
        return [KernelConfig(),
                KernelConfig(impl="xla", mxu_dtype="int32"),
                KernelConfig(impl="xla", mxu_dtype="f32")]

    def test_deterministic_winner_under_fake_timer(self):
        cache = AutotuneCache(None)
        times = {"pallas/int32": 30.0, "xla/int32": 10.0, "xla/f32": 20.0}

        def build(cfg):
            return lambda: f"{cfg.impl}/{cfg.mxu_dtype}"

        win = tune(("k", 1), self._candidates(), build, cache=cache,
                   timer=lambda thunk: times[thunk()])
        assert win == KernelConfig(impl="xla", mxu_dtype="int32")
        assert cache.stats.sweeps == 1

    def test_tie_breaks_by_candidate_order(self):
        """Equal times -> earliest candidate (the untuned default) wins:
        selection is reproducible under any timer."""
        cache = AutotuneCache(None)
        win = tune(("k", 2), self._candidates(),
                   lambda cfg: (lambda: None), cache=cache,
                   timer=lambda thunk: 7.0)
        assert win == KernelConfig()

    def test_failing_candidates_skipped(self):
        cache = AutotuneCache(None)

        def build(cfg):
            if cfg.impl == "pallas":
                raise RuntimeError("illegal tile")
            return lambda: None

        win = tune(("k", 3), self._candidates(), build, cache=cache,
                   timer=lambda thunk: 1.0)
        assert win.impl == "xla"

    def test_all_failing_raises(self):
        cache = AutotuneCache(None)
        with pytest.raises(RuntimeError):
            tune(("k", 4), self._candidates(),
                 lambda cfg: (_ for _ in ()).throw(RuntimeError()),
                 cache=cache, timer=lambda thunk: 1.0)

    def test_second_call_hits_never_resweeps(self):
        cache = AutotuneCache(None)
        calls = []

        def timer(thunk):
            calls.append(1)
            return 1.0

        for _ in range(3):
            tune(("k", 5), self._candidates(),
                 lambda cfg: (lambda: None), cache=cache, timer=timer)
        assert cache.stats.sweeps == 1
        assert len(calls) == len(self._candidates())
        assert cache.stats.hits == 2

    def test_distinct_keys_sweep_separately(self):
        """The forced-collision converse: fused and bitserial winners are
        tuned (and stored) independently even for identical shapes."""
        cache = AutotuneCache(None)
        kw = dict(epilogue=False, sparsity=False, backend="cpu")
        kf = matmul_key(8, 16, 8, _sched(), "fused", **kw)
        kb = matmul_key(8, 16, 8, _sched(), "bitserial", **kw)
        tune(kf, self._candidates(), lambda cfg: (lambda: None),
             cache=cache, timer=lambda t: 1.0)
        tune(kb, [KernelConfig(impl="xla", mxu_dtype="f32")],
             lambda cfg: (lambda: None), cache=cache,
             timer=lambda t: 1.0)
        assert cache.stats.sweeps == 2
        assert cache.get(kf) == KernelConfig()
        assert cache.get(kb) == KernelConfig(impl="xla", mxu_dtype="f32")


# ---------------------------------------------------------------------------
# End to end: ops-level autotune stays bit-exact and caches.
# ---------------------------------------------------------------------------


class TestOpsAutotune:
    def test_radix_matmul_autotune_bit_exact(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
        at.reset_default_cache()
        try:
            from repro.kernels import ops
            from repro.kernels.ref import radix_matmul_ref

            rng = np.random.default_rng(0)
            x = rng.integers(0, 16, (8, 24), dtype=np.uint8)
            w = rng.integers(-8, 8, (24, 8), dtype=np.int32)
            want = np.asarray(radix_matmul_ref(x, w, 4))
            base = np.asarray(ops.radix_matmul(x, w, None, 4))
            tuned = np.asarray(ops.radix_matmul(x, w, None, 4,
                                                autotune=True))
            np.testing.assert_array_equal(base, want)
            np.testing.assert_array_equal(tuned, want)
            stats = at.default_cache().stats
            assert stats.sweeps == 1
            # steady state: same problem again is a pure cache hit
            np.testing.assert_array_equal(
                np.asarray(ops.radix_matmul(x, w, None, 4, autotune=True)),
                want)
            assert at.default_cache().stats.sweeps == 1
            assert at.default_cache().stats.hits >= 1
        finally:
            at.reset_default_cache()
