"""Property tests for the packed radix KV cache (docs/lm.md §KV format).

The cache is the inter-step activation format of the LM serving path:
K/V live as T-bit radix levels (two-per-byte for T <= 4) with one f32
scale per (token, kv-head).  Locked here, via the optional-hypothesis
shim in tests/_hyp.py:

* ``_pack4`` / ``_unpack4`` are mutually inverse bijections on nibble
  tensors (hi nibble = even index);
* ``_encode_kv`` / ``_decode_kv`` round-trip within the quantization
  step bound scale/(2^T - 1), and levels never exceed the T-bit range;
* ``cache_update`` writes position p into ring slot p % W (sliding
  window) / slot p (full cache), and ``cache_read`` decodes what the
  last writes left there;
* bulk prefill encoding (``encode_cache_bulk``) is bit-identical to
  incrementally ``cache_update``-ing one token at a time — prefill and
  decode agree on every stored byte, packed or not.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import get_config
from repro.core import encoding
from repro.lm import radix as radix_lib

pytestmark = pytest.mark.lm


def _cfg(T=4, packed=False, quant="radix"):
    return dataclasses.replace(get_config("gemma_2b", smoke=True),
                               quant=quant, radix_steps=T,
                               radix_kv_pack=packed)


# ---------------------------------------------------------------------------
# nibble packing
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), half=st.integers(1, 8))
def test_pack4_unpack4_roundtrip(seed, half):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 16, size=(2, 3, 2, 2 * half)).astype(np.uint8)
    p = radix_lib._pack4(jnp.asarray(q))
    assert p.shape == q.shape[:-1] + (half,) and p.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(radix_lib._unpack4(p)), q)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_unpack4_pack4_inverse(seed):
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 256, size=(3, 5, 2, 4)).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(radix_lib._pack4(radix_lib._unpack4(jnp.asarray(p)))), p)


def test_pack4_nibble_order_is_hi_even():
    q = jnp.asarray([[1, 2, 3, 4]], jnp.uint8)
    np.testing.assert_array_equal(np.asarray(radix_lib._pack4(q)),
                                  [[0x12, 0x34]])


# ---------------------------------------------------------------------------
# encode/decode
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), T=st.integers(2, 8))
def test_encode_decode_kv_error_bound(seed, T):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 2, 8)) * 2.0
    q, s = radix_lib._encode_kv(x, T)
    lvl = encoding.max_level(T)
    assert q.dtype == jnp.uint8 and s.shape == x.shape[:-1]
    assert int(q.max()) <= lvl
    back = radix_lib._decode_kv(q, s, T, jnp.float32)
    bound = s[..., None] * (1.0 / lvl) + 1e-6      # half a level of 2s/lvl
    assert bool(jnp.all(jnp.abs(back - x) <= bound))


# ---------------------------------------------------------------------------
# ring-slot semantics
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(total=st.integers(1, 20), window=st.sampled_from([2, 4, 8]))
def test_cache_update_ring_slot_holds_last_window(total, window):
    """After writing positions 0..total-1 into a W-slot ring, slot p % W
    holds exactly position p for the last min(total, W) positions."""
    cfg = _cfg(quant="none")                        # exact store: read raw
    B, H, hd = 1, cfg.n_kv_heads, cfg.hd
    cache = radix_lib.init_cache_entry(cfg, B, window, jnp.float32)
    for p in range(total):
        val = jnp.full((B, 1, H, hd), float(p), jnp.float32)
        cache = radix_lib.cache_update(cache, val, -val, jnp.int32(p), cfg,
                                       window=window)
    k = np.asarray(cache["k"])
    for p in range(max(0, total - window), total):
        assert float(k[0, p % window, 0, 0]) == float(p), (p, total, window)


def test_cache_update_full_cache_slot_is_position():
    cfg = _cfg(T=4)
    B, S, H, hd = 2, 6, cfg.n_kv_heads, cfg.hd
    cache = radix_lib.init_cache_entry(cfg, B, S, jnp.float32)
    key = jax.random.PRNGKey(0)
    ks = jax.random.normal(key, (B, S, H, hd))
    for p in range(S):
        cache = radix_lib.cache_update(cache, ks[:, p:p + 1],
                                       -ks[:, p:p + 1], jnp.int32(p), cfg)
    kdec, vdec = radix_lib.cache_read(cache, cfg, jnp.float32)
    # position order preserved + decode error within the radix bound
    lvl = encoding.max_level(cfg.radix_steps)
    s = np.abs(np.asarray(ks)).max(-1) + 1e-9
    assert np.all(np.abs(np.asarray(kdec) - np.asarray(ks))
                  <= s[..., None] / lvl + 1e-6)
    # v stream (stored as -k) decodes within the same bound; not the exact
    # negation of kdec because round-half ties break asymmetrically
    assert np.all(np.abs(np.asarray(vdec) + np.asarray(ks))
                  <= s[..., None] / lvl + 1e-6)


# ---------------------------------------------------------------------------
# bulk prefill == incremental decode writes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [False, True])
def test_bulk_encode_bit_equals_incremental_updates(packed):
    cfg = _cfg(T=4, packed=packed)
    assert radix_lib._packed(cfg) == packed
    B, S, H, hd = 2, 5, cfg.n_kv_heads, cfg.hd
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    ks = jax.random.normal(k1, (B, S, H, hd))
    vs = jax.random.normal(k2, (B, S, H, hd))
    bulk = radix_lib.encode_cache_bulk(ks, vs, cfg, jnp.float32)
    inc = radix_lib.init_cache_entry(cfg, B, S, jnp.float32)
    for p in range(S):
        inc = radix_lib.cache_update(inc, ks[:, p:p + 1], vs[:, p:p + 1],
                                     jnp.int32(p), cfg)
    assert set(bulk) == set(inc) == {"k", "v", "k_scale", "v_scale"}
    for name in bulk:
        np.testing.assert_array_equal(np.asarray(bulk[name]),
                                      np.asarray(inc[name]), err_msg=name)


def test_packed_halves_bytes_and_roundtrips():
    cfg = _cfg(T=4, packed=True)
    B, S = 1, 3
    cache = radix_lib.init_cache_entry(cfg, B, S, jnp.float32)
    assert cache["k"].shape[-1] == cfg.hd // 2      # two levels per byte
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.n_kv_heads,
                                                  cfg.hd))
    cache = radix_lib.cache_update(cache, x, x, jnp.int32(0), cfg)
    kdec, _ = radix_lib.cache_read(cache, cfg, jnp.float32)
    lvl = encoding.max_level(cfg.radix_steps)
    s = np.abs(np.asarray(x)).max(-1) + 1e-9
    assert np.all(np.abs(np.asarray(kdec[:, :1]) - np.asarray(x))
                  <= s[..., None] / lvl + 1e-6)


def test_pack_gate_needs_t_at_most_4():
    assert not radix_lib._packed(_cfg(T=5, packed=True))
    assert radix_lib._packed(_cfg(T=4, packed=True))
    assert not radix_lib._packed(_cfg(T=4, packed=True, quant="none"))


def test_init_cache_entry_shapes_by_mode():
    B, S = 2, 7
    for cfg, kdtype, kshape in [
        (_cfg(quant="none"), jnp.float32, ("hd",)),
        (_cfg(T=6), jnp.uint8, ("hd",)),
        (_cfg(T=4, packed=True), jnp.uint8, ("hd2",)),
    ]:
        c = radix_lib.init_cache_entry(cfg, B, S, jnp.float32)
        hd = cfg.hd // 2 if kshape == ("hd2",) else cfg.hd
        assert c["k"].shape == (B, S, cfg.n_kv_heads, hd)
        assert c["k"].dtype == kdtype
        if radix_lib._radix_kv(cfg):
            assert c["k_scale"].shape == (B, S, cfg.n_kv_heads)
            assert c["k_scale"].dtype == jnp.float32
        else:
            assert set(c) == {"k", "v"}
