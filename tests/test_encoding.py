"""Radix encoding invariants (unit + property tests)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import encoding


class TestRoundTrip:
    @pytest.mark.parametrize("T", [1, 2, 3, 4, 5, 6, 8])
    def test_encode_decode_exhaustive(self, T):
        q = jnp.arange(encoding.max_level(T) + 1, dtype=jnp.int32)
        planes = encoding.encode(q, T)
        assert planes.shape == (T, q.shape[0])
        assert planes.dtype == jnp.int8
        assert bool(jnp.all((planes == 0) | (planes == 1)))
        np.testing.assert_array_equal(np.asarray(encoding.decode(planes)), np.asarray(q))

    def test_msb_first(self):
        # value 0b100 at T=3: spike at t=0 only (earliest spike = MSB)
        planes = encoding.encode(jnp.asarray([4], jnp.int32), 3)
        np.testing.assert_array_equal(np.asarray(planes).ravel(), [1, 0, 0])

    def test_pack_is_decode(self):
        q = jnp.asarray(np.random.default_rng(0).integers(0, 16, (5, 7)), jnp.int32)
        planes = encoding.encode(q, 4)
        np.testing.assert_array_equal(
            np.asarray(encoding.pack_planes(planes)), np.asarray(q).astype(np.uint8))


class TestQuantize:
    def test_clip_and_floor(self):
        x = jnp.asarray([-0.5, 0.0, 0.49, 0.999, 1.0, 2.0])
        q = encoding.quantize(x, 4, 1.0)  # levels 0..15, floor(x*16)
        np.testing.assert_array_equal(np.asarray(q), [0, 0, 7, 15, 15, 15])

    def test_scale(self):
        x = jnp.asarray([2.0])
        assert int(encoding.quantize(x, 3, 4.0)[0]) == 4  # 2/4*8

    @given(st.floats(0.0, 1.0, allow_nan=False), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_quant_error_bound(self, x, T):
        """|dequant(quant(x)) - x| < scale / 2^T — the radix-encoding error
        bound that drives the paper's accuracy-vs-T trade-off (Table I)."""
        q = encoding.quantize(jnp.float32(x), T, 1.0)
        err = abs(float(encoding.dequantize(q, T, 1.0)) - x)
        assert err < 1.0 / (1 << T) + 1e-6


class TestRadixVsRate:
    def test_rate_needs_exponentially_more_steps(self):
        """The paper's motivation: radix T=4 precision requires ~2^4 rate steps."""
        x = jnp.asarray(np.linspace(0, 1, 101), jnp.float32)
        radix_err = float(jnp.max(jnp.abs(
            encoding.dequantize(encoding.quantize(x, 4), 4) - x)))
        rate4 = encoding.rate_encode(x, 4)
        rate16 = encoding.rate_encode(x, 16)
        err4 = float(jnp.max(jnp.abs(encoding.rate_decode(rate4) - x)))
        err16 = float(jnp.max(jnp.abs(encoding.rate_decode(rate16) - x)))
        assert radix_err < err4          # same steps: radix strictly better
        assert abs(err16 - radix_err) < 0.05  # rate needs 2^T steps to match

    def test_rate_decode_counts(self):
        planes = encoding.rate_encode(jnp.asarray([0.5]), 8)
        assert abs(float(encoding.rate_decode(planes)[0]) - 0.5) <= 1 / 8


@given(
    st.lists(st.integers(0, 255), min_size=1, max_size=32),
    st.integers(1, 8),
)
@settings(max_examples=100, deadline=None)
def test_property_roundtrip_random(levels, T):
    lvl = encoding.max_level(T)
    q = jnp.asarray([min(v, lvl) for v in levels], jnp.int32)
    assert np.array_equal(np.asarray(encoding.decode(encoding.encode(q, T))), np.asarray(q))


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_property_radix_weights_sum(T):
    # sum of all weights == max level (all-ones train decodes to 2^T - 1)
    w = encoding.radix_weights(T)
    assert int(w.sum()) == encoding.max_level(T)


@given(
    st.integers(1, 8),                        # T
    st.integers(1, 6), st.integers(1, 6),     # shape (rows, cols)
    st.floats(0.05, 8.0, allow_nan=False),    # scale
    st.integers(0, 2 ** 31 - 1),              # data seed
)
@settings(max_examples=100, deadline=None)
def test_property_quantize_encode_spikesum_roundtrip(T, rows, cols, scale,
                                                     seed):
    """quantize -> encode -> weighted spike sum recovers the quantized
    levels exactly: the spike train of length T *is* the binary expansion,
    so sum_t spikes[t] * 2^(T-1-t) == q for every input, shape and scale."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-scale, 2 * scale, (rows, cols)), jnp.float32)
    q = encoding.quantize(x, T, scale)
    planes = encoding.encode(q, T)
    weights = encoding.radix_weights(T).reshape((T, 1, 1))
    spike_sum = (planes.astype(jnp.int32) * weights).sum(axis=0)
    np.testing.assert_array_equal(np.asarray(spike_sum),
                                  np.asarray(q, dtype=np.int32))
    assert int(jnp.max(q)) <= encoding.max_level(T)
