"""Batch-bucketing plan cache behavior + cache-key identity hygiene.

The serving contract (DESIGN.md §3): arbitrary request sizes never
recompile on the hot path.  Requests pad up to a pre-compiled bucket (or
chunk by the top bucket), results slice back bit-exactly, cache entries
die with their ``QuantizedNet``, and the stats counters prove all of it.
Public-surface behavior runs through ``repro.api.Executable``; the
low-level weakref keying/pruning mechanics are pinned directly on the
engine's internal ``PlanCache``/``_cached_plan`` machinery.
"""

import gc
import weakref

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import conversion, engine
from repro.models import lenet

RNG = np.random.default_rng(3)


def _qnet(T=4, width_mult=0.25, pool_mode="or"):
    static, params, input_hw = lenet.make(pool_mode=pool_mode,
                                          width_mult=width_mult)
    calib = jnp.asarray(RNG.uniform(0, 1, (4,) + input_hw), jnp.float32)
    return conversion.convert(static, params, calib, num_steps=T), input_hw


def _x(batch, input_hw):
    return jnp.asarray(RNG.uniform(0, 1, (batch,) + input_hw), jnp.float32)


def _exe(qnet, input_hw, buckets, **kw):
    return api.Accelerator(**kw).compile(qnet, input_hw, buckets=buckets)


# ---------------------------------------------------------------------------
# Bucket ladder.
# ---------------------------------------------------------------------------


def test_bucket_ladder_selection():
    cache = engine.PlanCache(buckets=(8, 1, 32))     # unsorted on purpose
    assert cache.buckets == (1, 8, 32)
    assert cache.bucket_for(1) == 1
    assert cache.bucket_for(2) == 8
    assert cache.bucket_for(8) == 8
    assert cache.bucket_for(9) == 32
    assert cache.bucket_for(33) == 32                # oversize -> top bucket
    with pytest.raises(ValueError):
        cache.bucket_for(0)
    with pytest.raises(ValueError):
        engine.PlanCache(buckets=())
    with pytest.raises(ValueError):
        engine.PlanCache(buckets=(0, 4))
    with pytest.raises(ValueError, match="data_parallel"):
        engine.PlanCache(buckets=(1,), data_parallel=0)


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 11, 19])
def test_pad_slice_roundtrip_bit_exact(n):
    """Any request size through the ladder == the direct oracle; padding
    rows never leak into the sliced-back logits."""
    qnet, input_hw = _qnet()
    exe = _exe(qnet, input_hw, (1, 4, 8))
    x = _x(n, input_hw)
    ref = api.oracle(qnet, x, mode="packed")
    got = exe(x)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_cache_hit_on_repeated_shapes():
    qnet, input_hw = _qnet()
    exe = _exe(qnet, input_hw, (1, 4))
    exe(_x(3, input_hw))
    compiles = exe.stats()["compiles"]
    hits = exe.stats()["hits"]
    exe(_x(3, input_hw))
    exe(_x(2, input_hw))     # same bucket (4)
    assert exe.stats()["compiles"] == compiles
    assert exe.stats()["hits"] == hits + 2


def test_no_recompiles_across_mixed_sizes_after_warmup():
    qnet, input_hw = _qnet()
    exe = _exe(qnet, input_hw, (1, 4, 8)).warmup()
    assert exe.stats()["compiles"] == 3
    for n in (5, 1, 3, 8, 2, 17, 4, 7):              # 17 chunks via top
        exe(_x(n, input_hw))
    stats = exe.stats()
    assert stats["compiles"] == 3                    # zero steady-state
    assert stats["padded_rows"] > 0
    assert stats["executions"] > 8                   # chunking ran extra


def test_oversize_request_chunks_by_top_bucket():
    qnet, input_hw = _qnet()
    exe = _exe(qnet, input_hw, (2, 4))
    x = _x(11, input_hw)                             # 4 + 4 + pad(3->4)
    ref = api.oracle(qnet, x, mode="packed")
    execs = exe.stats()["executions"]
    got = exe(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert exe.stats()["executions"] == execs + 3
    assert exe.stats()["padded_rows"] == 1


def test_weakref_pruning_on_net_gc():
    cache = engine.PlanCache(buckets=(1,))
    qnet, input_hw = _qnet()
    cache.run(qnet, _x(1, input_hw))

    def scoped():
        q2, hw = _qnet(width_mult=0.125)
        cache.run(q2, _x(1, hw))

    scoped()
    gc.collect()
    assert len(cache) == 2                           # dead entry still held
    assert cache.prune() == 1                        # explicit prune drops it
    assert len(cache) == 1 and cache.stats.pruned == 1
    # pruning also happens automatically on the next miss
    def scoped2():
        q3, hw = _qnet(width_mult=0.5)
        cache.run(q3, _x(1, hw))
    scoped2()
    gc.collect()
    q4, hw = _qnet(T=3)
    cache.run(q4, _x(1, hw))                         # miss -> auto-prune
    assert cache.stats.pruned == 2
    assert all(r() is not None for r, _ in cache._plans.values())


# ---------------------------------------------------------------------------
# Cache-key identity: keyed by the weakref itself, never a recyclable id().
# ---------------------------------------------------------------------------


def test_cached_plan_key_survives_id_recycling():
    """Regression for the old ``(id(qnet), shape, method)`` keys: after a
    net dies, CPython readily hands its id() to the next allocation, so an
    id-keyed dict entry for net A could be *found* by lookalike net B.
    Keys are now ``(weakref(qnet), ...)``: a dead ref never compares equal
    to a live one, so the collision is structurally impossible — B must
    always get its own freshly compiled plan."""
    qnet, input_hw = _qnet()
    shape = (1,) + input_hw
    plan_a = engine._cached_plan(qnet, shape, "fused")
    ref_a = weakref.ref(qnet)
    key_a = (ref_a, shape, "fused")
    assert key_a in engine._PLAN_CACHE
    recycled = id(qnet)
    del qnet
    gc.collect()
    assert ref_a() is None
    # force the historical collision: allocate nets until one lands on the
    # dead net's id (usually the first try — same type, same size class).
    q_b = None
    for _ in range(8):
        cand, _hw = _qnet()
        if id(cand) == recycled:
            q_b = cand
            break
    if q_b is None:                                  # allocator didn't reuse
        q_b, _hw = _qnet()
    # the dead ref can never alias the new net's key ...
    assert (weakref.ref(q_b), shape, "fused") != key_a
    # ... so B compiles its own plan instead of being served A's.
    plan_b = engine._cached_plan(q_b, shape, "fused")
    assert plan_b is not plan_a
    assert engine._cached_plan(q_b, shape, "fused") is plan_b


def test_plan_cache_keys_are_weakrefs():
    qnet, input_hw = _qnet()
    cache = engine.PlanCache(buckets=(1,))
    cache.run(qnet, _x(1, input_hw))
    (key,) = cache._plans.keys()
    assert isinstance(key[0], weakref.ref) and key[0]() is qnet


# ---------------------------------------------------------------------------
# Data-parallel bucket plans.
# ---------------------------------------------------------------------------


def test_data_parallel_bucket_plans_match(monkeypatch):
    """Buckets shard over devices (gcd fallback) and stay bit-exact; the
    test session runs with 8 placeholder CPU devices (conftest.py)."""
    qnet, input_hw = _qnet()
    ndev = len(jax.devices())
    exe = _exe(qnet, input_hw, (1, 8)).warmup()
    plans = [exe.plan_for(b) for b in exe.buckets]
    assert plans[0].data_parallel == 1               # bucket 1: fallback
    assert plans[1].data_parallel == np.gcd(8, ndev)
    x = _x(6, input_hw)
    ref = api.oracle(qnet, x, mode="packed")
    np.testing.assert_array_equal(np.asarray(exe(x)), np.asarray(ref))


def test_data_parallel_validation():
    qnet, input_hw = _qnet()
    with pytest.raises(ValueError, match="not divisible"):
        engine._compile_plan_impl(qnet, (3,) + input_hw, data_parallel=2)
    with pytest.raises(ValueError, match="devices"):
        engine._compile_plan_impl(qnet, (1024,) + input_hw,
                                  data_parallel=512)
    with pytest.raises(ValueError, match="data_parallel"):
        engine._compile_plan_impl(qnet, (4,) + input_hw, data_parallel=0)
