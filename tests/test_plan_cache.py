"""Batch-bucketing plan cache + engine.run argument validation.

The serving contract (DESIGN.md §3): arbitrary request sizes never
recompile on the hot path.  Requests pad up to a pre-compiled bucket (or
chunk by the top bucket), results slice back bit-exactly, cache entries
die with their ``QuantizedNet``, and the stats counters prove all of it.
"""

import gc
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conversion, engine
from repro.models import lenet

RNG = np.random.default_rng(3)


def _qnet(T=4, width_mult=0.25, pool_mode="or"):
    static, params, input_hw = lenet.make(pool_mode=pool_mode,
                                          width_mult=width_mult)
    calib = jnp.asarray(RNG.uniform(0, 1, (4,) + input_hw), jnp.float32)
    return conversion.convert(static, params, calib, num_steps=T), input_hw


def _x(batch, input_hw):
    return jnp.asarray(RNG.uniform(0, 1, (batch,) + input_hw), jnp.float32)


# ---------------------------------------------------------------------------
# Bucket ladder.
# ---------------------------------------------------------------------------


def test_bucket_ladder_selection():
    cache = engine.PlanCache(buckets=(8, 1, 32))     # unsorted on purpose
    assert cache.buckets == (1, 8, 32)
    assert cache.bucket_for(1) == 1
    assert cache.bucket_for(2) == 8
    assert cache.bucket_for(8) == 8
    assert cache.bucket_for(9) == 32
    assert cache.bucket_for(33) == 32                # oversize -> top bucket
    with pytest.raises(ValueError):
        cache.bucket_for(0)
    with pytest.raises(ValueError):
        engine.PlanCache(buckets=())
    with pytest.raises(ValueError):
        engine.PlanCache(buckets=(0, 4))
    with pytest.raises(ValueError, match="data_parallel"):
        engine.PlanCache(buckets=(1,), data_parallel=0)


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 11, 19])
def test_pad_slice_roundtrip_bit_exact(n):
    """Any request size through the ladder == the direct jnp path; padding
    rows never leak into the sliced-back logits."""
    qnet, input_hw = _qnet()
    cache = engine.PlanCache(buckets=(1, 4, 8))
    x = _x(n, input_hw)
    ref = engine.run(qnet, x, mode="packed", backend="jnp")
    got = cache.run(qnet, x)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_cache_hit_on_repeated_shapes():
    qnet, input_hw = _qnet()
    cache = engine.PlanCache(buckets=(1, 4))
    cache.run(qnet, _x(3, input_hw))
    compiles = cache.stats.compiles
    hits = cache.stats.hits
    cache.run(qnet, _x(3, input_hw))
    cache.run(qnet, _x(2, input_hw))     # same bucket (4)
    assert cache.stats.compiles == compiles
    assert cache.stats.hits == hits + 2


def test_no_recompiles_across_mixed_sizes_after_warmup():
    qnet, input_hw = _qnet()
    cache = engine.PlanCache(buckets=(1, 4, 8))
    cache.warmup(qnet, input_hw)
    assert cache.stats.compiles == 3
    for n in (5, 1, 3, 8, 2, 17, 4, 7):              # 17 chunks via top
        cache.run(qnet, _x(n, input_hw))
    assert cache.stats.compiles == 3                 # zero steady-state
    assert cache.stats.padded_rows > 0
    assert cache.stats.executions > 8                # chunking ran extra


def test_oversize_request_chunks_by_top_bucket():
    qnet, input_hw = _qnet()
    cache = engine.PlanCache(buckets=(2, 4))
    x = _x(11, input_hw)                             # 4 + 4 + pad(3->4)
    ref = engine.run(qnet, x, mode="packed", backend="jnp")
    execs = cache.stats.executions
    got = cache.run(qnet, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert cache.stats.executions == execs + 3
    assert cache.stats.padded_rows == 1


def test_weakref_pruning_on_net_gc():
    cache = engine.PlanCache(buckets=(1,))
    qnet, input_hw = _qnet()
    cache.run(qnet, _x(1, input_hw))

    def scoped():
        q2, hw = _qnet(width_mult=0.125)
        cache.run(q2, _x(1, hw))

    scoped()
    gc.collect()
    assert len(cache) == 2                           # dead entry still held
    assert cache.prune() == 1                        # explicit prune drops it
    assert len(cache) == 1 and cache.stats.pruned == 1
    # pruning also happens automatically on the next miss
    def scoped2():
        q3, hw = _qnet(width_mult=0.5)
        cache.run(q3, _x(1, hw))
    scoped2()
    gc.collect()
    q4, hw = _qnet(T=3)
    cache.run(q4, _x(1, hw))                         # miss -> auto-prune
    assert cache.stats.pruned == 2
    assert all(r() is not None for r, _ in cache._plans.values())


def test_data_parallel_bucket_plans_match(monkeypatch):
    """Buckets shard over devices (gcd fallback) and stay bit-exact; the
    test session runs with 8 placeholder CPU devices (conftest.py)."""
    qnet, input_hw = _qnet()
    ndev = len(jax.devices())
    cache = engine.PlanCache(buckets=(1, 8))
    plans = cache.warmup(qnet, input_hw)
    assert plans[0].data_parallel == 1               # bucket 1: fallback
    assert plans[1].data_parallel == np.gcd(8, ndev)
    x = _x(6, input_hw)
    ref = engine.run(qnet, x, mode="packed", backend="jnp")
    np.testing.assert_array_equal(np.asarray(cache.run(qnet, x)),
                                  np.asarray(ref))


def test_data_parallel_validation():
    qnet, input_hw = _qnet()
    with pytest.raises(ValueError, match="not divisible"):
        engine.compile_plan(qnet, (3,) + input_hw, data_parallel=2)
    with pytest.raises(ValueError, match="devices"):
        engine.compile_plan(qnet, (1024,) + input_hw,
                            data_parallel=512)
    with pytest.raises(ValueError, match="data_parallel"):
        engine.compile_plan(qnet, (4,) + input_hw, data_parallel=0)


# ---------------------------------------------------------------------------
# engine.run argument validation (previously silent fall-throughs).
# ---------------------------------------------------------------------------


class TestRunArgValidation:
    def test_snn_on_kernels_backend_raises(self):
        qnet, input_hw = _qnet()
        with pytest.raises(ValueError, match="packed-level path only"):
            engine.run(qnet, _x(1, input_hw), mode="snn", backend="kernels")

    def test_unknown_mode_backend_method_raise(self):
        qnet, input_hw = _qnet()
        x = _x(1, input_hw)
        with pytest.raises(ValueError, match="mode"):
            engine.run(qnet, x, mode="spiking")
        with pytest.raises(ValueError, match="backend"):
            engine.run(qnet, x, backend="xla")
        with pytest.raises(ValueError, match="method"):
            engine.run(qnet, x, backend="kernels", method="horner")

    def test_method_on_jnp_backend_warns(self):
        qnet, input_hw = _qnet()
        x = _x(1, input_hw)
        with pytest.warns(UserWarning, match="ignored with backend='jnp'"):
            engine.run(qnet, x, backend="jnp", method="bitserial")

    def test_default_combinations_stay_silent(self):
        qnet, input_hw = _qnet()
        x = _x(1, input_hw)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            engine.run(qnet, x)
            engine.run(qnet, x, mode="snn")
            engine.run(qnet, x, backend="kernels")
            engine.run(qnet, x, backend="kernels", method="bitserial")
