"""Temporal coding expansion: TTFS + phase EncodingSpecs (ISSUE 4).

The paper's claim is one accelerator supporting *emerging neural
encodings*; this suite proves the two temporal schemes are first-class:

* declarations (levels math, packed bits, plane weights, period grids),
* decode round-trip ``decode(encode(q)) == q`` across ALL four specs over
  their representable level grids (exhaustive + property-based),
* ``validate_static`` error paths: every illegal (encoding, pool) pairing
  raises with the supported options named — nothing silently falls
  through,
* end-to-end plan-vs-``api.oracle`` bit-exactness on LeNet-5 and Fang
  CNN-2 (TTFS and phase BOTH on the kernels backend, both dataflows —
  TTFS through its pow2 epilogue grid and the occupancy-gated plane
  schedule, phase through the period-repeated bitserial schedule),
* the kernel-level period schedule and the pow2 epilogue grid against
  the ref.py oracles (tests/test_sparsity_prepass.py covers the
  occupancy machinery itself).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro import api
from repro.core import conversion, encoding
from repro.kernels import ops, ref
from repro.kernels.radix_matmul import radix_matmul_pallas
from repro.models import fang, lenet

RNG = np.random.default_rng(29)

ALL_SPECS = [api.RadixEncoding(4), api.RateEncoding(6),
             api.TTFSEncoding(4), api.PhaseEncoding(8, periods=2)]


def _make(maker=lenet, pool_mode="avg", width_mult=0.25, **convert_kw):
    static, params, input_hw = maker.make(pool_mode=pool_mode,
                                          width_mult=width_mult)
    calib = jnp.asarray(RNG.uniform(0, 1, (4,) + input_hw), jnp.float32)
    qnet = conversion.convert(static, params, calib, **convert_kw)
    return qnet, input_hw


def _x(batch, input_hw):
    return jnp.asarray(RNG.uniform(0, 1, (batch,) + input_hw), jnp.float32)


# ---------------------------------------------------------------------------
# Declarations: the specs' own capability statements.
# ---------------------------------------------------------------------------


class TestDeclarations:
    def test_ttfs(self):
        spec = api.TTFSEncoding(4)
        assert spec.levels == 16                      # grid units
        assert spec.backends == ("kernels", "jnp")
        assert spec.kernel_dataflows == ("fused", "bitserial")
        assert spec.validate_dataflow(None) == "fused"
        assert spec.pool_modes == ("avg", "max")
        assert spec.radix_planes
        np.testing.assert_array_equal(spec.representable_levels(),
                                      [0, 1, 2, 4, 8])
        np.testing.assert_array_equal(spec.plane_weights(), [8, 4, 2, 1])
        # the kernels run TTFS through its declared schedule: radix
        # extraction of the one-hot planes + pow2 epilogue grid (the
        # in-kernel log-spaced re-timing of the single output spike)
        sched = spec.kernel_schedule()
        assert (sched.packed_bits, sched.periods) == (4, 1)
        assert sched.out_level == 15 and sched.out_grid == "pow2"

    def test_phase(self):
        spec = api.PhaseEncoding(8, periods=2)
        assert spec.phases == 4 and spec.packed_bits == 4
        assert spec.levels == 16 and spec.max_level == 15
        assert spec.backends == ("kernels", "jnp")
        assert spec.kernel_dataflows == ("fused", "bitserial")
        assert spec.validate_dataflow(None) == "fused"
        assert not spec.radix_planes                  # repeated periods
        assert api.PhaseEncoding(4).radix_planes      # P=1 is plain radix
        np.testing.assert_array_equal(spec.plane_weights(),
                                      [8, 4, 2, 1, 8, 4, 2, 1])

    def test_phase_period_validation(self):
        with pytest.raises(ValueError, match="divisible"):
            api.PhaseEncoding(7, periods=2)
        with pytest.raises(ValueError, match="periods"):
            api.PhaseEncoding(4, periods=0)

    def test_specs_hashable_and_distinct(self):
        assert api.PhaseEncoding(4) != api.RadixEncoding(4)
        assert api.PhaseEncoding(8, periods=2) != api.PhaseEncoding(8)
        assert api.TTFSEncoding(4) != api.RadixEncoding(4)
        assert len(set(ALL_SPECS)) == 4

    def test_registry_covers_all(self):
        assert [cls.name for cls in api.SPECS] == [
            "radix", "rate", "ttfs", "phase"]

    def test_ttfs_single_spike(self):
        """At most ONE spike per activation — the TTFS sparsity claim."""
        spec = api.TTFSEncoding(5)
        planes = spec.encode(jnp.arange(32))
        assert int(planes.sum(0).max()) == 1
        assert int(planes.sum(0).min()) == 0          # q = 0: empty train

    def test_ttfs_timing_is_value(self):
        """Larger value -> earlier spike: t = T - 1 - msb(q)."""
        spec = api.TTFSEncoding(4)
        planes = np.asarray(spec.encode(jnp.asarray([8, 4, 2, 1])))
        assert [int(planes[:, i].argmax()) for i in range(4)] == [0, 1, 2, 3]

    def test_sparsity_ordering(self):
        """Mean spikes/activation: ttfs <= radix <= phase (P x radix)."""
        q = jnp.arange(16)
        n = lambda s: float(s.encode(q).sum()) / 16
        ttfs = n(api.TTFSEncoding(4))
        radix = n(api.RadixEncoding(4))
        phase = n(api.PhaseEncoding(8, periods=2))
        assert ttfs < radix < phase
        assert phase == pytest.approx(2 * radix)


# ---------------------------------------------------------------------------
# Decode round-trip across every spec (the encode/decode contract).
# ---------------------------------------------------------------------------


class TestRoundTrip:
    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_exhaustive_roundtrip(self, spec):
        q = jnp.asarray(spec.representable_levels(), jnp.int32)
        planes = spec.encode(q)
        assert planes.shape == (spec.num_steps, q.shape[0])
        assert bool(jnp.all((planes == 0) | (planes == 1)))
        np.testing.assert_array_equal(np.asarray(spec.decode(planes)),
                                      np.asarray(q))

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_decode_is_weighted_plane_reduce(self, spec):
        """decode == reduce_planes on raw planes: the plane-weight algebra
        (DESIGN.md §7) in its purest form."""
        q = jnp.asarray(spec.representable_levels(), jnp.int32)
        planes = spec.encode(q)
        np.testing.assert_array_equal(np.asarray(spec.decode(planes)),
                                      np.asarray(spec.reduce_planes(planes)))
        w = spec.plane_weights().reshape(spec.num_steps, 1)
        manual = (np.asarray(planes, np.int64) * w).sum(0) // spec.periods
        np.testing.assert_array_equal(manual, np.asarray(q))

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_quantize_lands_on_grid(self, spec):
        """quantize/requantize may only emit representable levels."""
        x = jnp.asarray(RNG.uniform(-0.5, 1.5, 256), jnp.float32)
        grid = set(spec.representable_levels().tolist())
        assert set(np.asarray(spec.quantize(x)).tolist()) <= grid
        acc = jnp.asarray(RNG.integers(-500, 500, 256), jnp.int32)
        assert set(np.asarray(spec.requantize(acc, 0.07)).tolist()) <= grid

    @given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_property_roundtrip(self, T, seed):
        rng = np.random.default_rng(seed)
        for spec in (api.RadixEncoding(T), api.RateEncoding(T),
                     api.TTFSEncoding(T),
                     api.PhaseEncoding(2 * T, periods=2)):
            grid = spec.representable_levels()
            q = jnp.asarray(rng.choice(grid, 17), jnp.int32)
            np.testing.assert_array_equal(
                np.asarray(spec.decode(spec.encode(q))), np.asarray(q))


# ---------------------------------------------------------------------------
# validate_static / compile-time error paths for all four specs.
# ---------------------------------------------------------------------------


POOL_CASES = [
    (api.RadixEncoding(4), "or", True),
    (api.RadixEncoding(4), "avg", True),
    (api.RadixEncoding(4), "max", True),
    (api.RateEncoding(6), "avg", True),
    (api.RateEncoding(6), "or", False),
    (api.RateEncoding(6), "max", False),
    (api.TTFSEncoding(4), "avg", True),
    (api.TTFSEncoding(4), "max", True),
    (api.TTFSEncoding(4), "or", False),
    (api.PhaseEncoding(8, periods=2), "or", True),
    (api.PhaseEncoding(8, periods=2), "avg", True),
    (api.PhaseEncoding(8, periods=2), "max", True),
]


class TestValidation:
    @pytest.mark.parametrize(
        "spec,pool,ok", POOL_CASES,
        ids=[f"{s.name}-{p}" for s, p, _ in POOL_CASES])
    def test_pool_pairings(self, spec, pool, ok):
        static = (("conv", {}), ("pool", {"window": 2, "mode": pool}),
                  ("flatten", {}), ("linear", {}))
        if ok:
            spec.validate_static(static)
        else:
            with pytest.raises(ValueError) as e:
                spec.validate_static(static)
            # actionable: names the offending mode AND the supported ones
            assert pool in str(e.value) and "supported" in str(e.value)
            for good in spec.pool_modes:
                assert good in str(e.value)

    def test_rate_on_kernels_backend_raises(self):
        """rate is the one remaining jnp-only spec — its sigma-delta
        planes are not the bit planes of its packed count, so the
        kernels path stays undeclared and the facade refuses loudly."""
        qnet, hw = _make(encoding=api.RateEncoding(6))
        with pytest.raises(ValueError, match="kernels"):
            api.Accelerator(backend="kernels").compile(qnet, hw)

    def test_rate_spec_rejected_by_kernel_wrappers(self):
        with pytest.raises(ValueError, match="kernel dataflow"):
            ops._schedule(api.RateEncoding(6))

    def test_specs_accepted_by_kernel_wrappers(self):
        """ops._schedule resolves every kernels-capable spec (and bare
        ints) to its declared KernelSchedule."""
        sched = ops._schedule(api.PhaseEncoding(8, periods=2))
        assert (sched.packed_bits, sched.periods) == (4, 2)
        assert sched.out_grid == "dense"
        sched = ops._schedule(api.RadixEncoding(4))
        assert (sched.packed_bits, sched.periods) == (4, 1)
        sched = ops._schedule(api.TTFSEncoding(4))
        assert (sched.packed_bits, sched.periods) == (4, 1)
        assert sched.out_grid == "pow2"
        sched = ops._schedule(5)
        assert (sched.packed_bits, sched.periods, sched.out_level,
                sched.out_grid) == (5, 1, 31, "dense")

    def test_convert_rejects_bad_pools(self):
        static, params, input_hw = lenet.make(pool_mode="or",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (2,) + input_hw), jnp.float32)
        with pytest.raises(ValueError, match="pool mode"):
            conversion.convert(static, params, calib,
                               encoding=api.TTFSEncoding(4))

    def test_phase_unknown_dataflow_raises(self):
        qnet, hw = _make(encoding=api.PhaseEncoding(8, periods=2))
        with pytest.raises(ValueError, match="dataflow"):
            api.Accelerator(dataflow="horner").compile(qnet, hw,
                                                       buckets=(1,))


# ---------------------------------------------------------------------------
# End-to-end: plan vs oracle, bit-exact (LeNet-5 + Fang CNN-2).
# ---------------------------------------------------------------------------


class TestTTFSEndToEnd:
    @pytest.mark.parametrize("pool", ["avg", "max"])
    def test_lenet_plan_vs_oracle(self, pool):
        qnet, hw = _make(pool_mode=pool, encoding=api.TTFSEncoding(4))
        exe = api.Accelerator(backend="jnp").compile(qnet, hw,
                                                     buckets=(1, 4))
        for n in (1, 3, 6):
            x = _x(n, hw)
            want = api.oracle(qnet, x, mode="snn")
            np.testing.assert_array_equal(
                np.asarray(api.oracle(qnet, x, mode="packed")),
                np.asarray(want))
            np.testing.assert_array_equal(np.asarray(exe(x)),
                                          np.asarray(want))

    def test_fang_plan_vs_oracle(self):
        qnet, hw = _make(fang, encoding=api.TTFSEncoding(5))
        exe = api.Accelerator(backend="jnp").compile(qnet, hw, buckets=(2,))
        x = _x(2, hw)
        np.testing.assert_array_equal(
            np.asarray(exe(x)),
            np.asarray(api.oracle(qnet, x, mode="snn")))

    @pytest.mark.parametrize("dataflow", ["fused", "bitserial"])
    @pytest.mark.parametrize("pool", ["avg", "max"])
    def test_lenet_kernels_vs_oracle(self, dataflow, pool):
        """Acceptance: TTFS LeNet-5 on the KERNELS backend, both
        dataflows, bit-exact vs the spike-plane oracle — the pow2
        epilogue grid and the occupancy-gated plane schedule change
        nothing but the work done."""
        qnet, hw = _make(pool_mode=pool, encoding=api.TTFSEncoding(4))
        exe = api.Accelerator(backend="kernels", dataflow=dataflow).compile(
            qnet, hw, buckets=(1, 4))
        for n in (1, 3):
            x = _x(n, hw)
            want = api.oracle(qnet, x, mode="snn")
            np.testing.assert_array_equal(np.asarray(exe(x)),
                                          np.asarray(want))
        stats = exe.stats()
        assert stats["plane_passes_total"] > 0

    @pytest.mark.parametrize("dataflow", ["fused", "bitserial"])
    def test_fang_kernels_vs_oracle(self, dataflow):
        """Acceptance: TTFS Fang CNN-2 on the KERNELS backend, both
        dataflows, bit-exact vs the spike-plane oracle."""
        qnet, hw = _make(fang, encoding=api.TTFSEncoding(5))
        exe = api.Accelerator(backend="kernels", dataflow=dataflow).compile(
            qnet, hw, buckets=(2,))
        x = _x(2, hw)
        np.testing.assert_array_equal(
            np.asarray(exe(x)),
            np.asarray(api.oracle(qnet, x, mode="snn")))

    def test_ttfs_less_precise_than_radix(self):
        """Log-spaced levels: TTFS tracks the float net worse than radix
        at equal T — the sparsity-for-precision trade, measured."""
        static, params, input_hw = lenet.make(pool_mode="avg",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (8,) + input_hw), jnp.float32)
        float_ref = conversion.float_forward(static, params, calib)
        errs = {}
        for spec in (api.RadixEncoding(4), api.TTFSEncoding(4)):
            qnet = conversion.convert(static, params, calib, encoding=spec,
                                      weight_bits=8)
            out = api.oracle(qnet, calib, mode="packed")
            errs[spec.name] = float(jnp.mean(jnp.abs(out - float_ref)))
        assert errs["radix"] < errs["ttfs"]


class TestPhaseEndToEnd:
    @pytest.mark.parametrize("dataflow", ["fused", "bitserial"])
    def test_lenet_kernels_vs_oracle(self, dataflow):
        qnet, hw = _make(pool_mode="or",
                         encoding=api.PhaseEncoding(8, periods=2))
        exe = api.Accelerator(backend="kernels", dataflow=dataflow).compile(
            qnet, hw, buckets=(1, 4))
        for n in (1, 5):
            x = _x(n, hw)
            want = api.oracle(qnet, x, mode="snn")
            np.testing.assert_array_equal(
                np.asarray(api.oracle(qnet, x, mode="packed")),
                np.asarray(want))
            np.testing.assert_array_equal(np.asarray(exe(x)),
                                          np.asarray(want))

    def test_fang_kernels_vs_oracle(self):
        qnet, hw = _make(fang, encoding=api.PhaseEncoding(6, periods=2))
        exe = api.Accelerator(backend="kernels",
                              dataflow="bitserial").compile(qnet, hw,
                                                            buckets=(2,))
        x = _x(2, hw)
        np.testing.assert_array_equal(
            np.asarray(exe(x)),
            np.asarray(api.oracle(qnet, x, mode="snn")))

    def test_phase_jnp_vs_oracle(self):
        qnet, hw = _make(pool_mode="max",
                         encoding=api.PhaseEncoding(6, periods=3))
        exe = api.Accelerator(backend="jnp").compile(qnet, hw, buckets=(2,))
        x = _x(2, hw)
        want = api.oracle(qnet, x, mode="snn")
        np.testing.assert_array_equal(
            np.asarray(api.oracle(qnet, x, mode="packed")),
            np.asarray(want))
        np.testing.assert_array_equal(np.asarray(exe(x)), np.asarray(want))

    def test_single_period_phase_equals_radix(self):
        """P = 1 phase coding IS radix coding: identical folded algebra,
        identical outputs."""
        static, params, input_hw = lenet.make(pool_mode="or",
                                              width_mult=0.25)
        calib = jnp.asarray(RNG.uniform(0, 1, (4,) + input_hw), jnp.float32)
        q_phase = conversion.convert(static, params, calib,
                                     encoding=api.PhaseEncoding(4))
        q_radix = conversion.convert(static, params, calib,
                                     encoding=api.RadixEncoding(4))
        x = _x(2, input_hw)
        np.testing.assert_array_equal(
            np.asarray(api.oracle(q_phase, x, mode="snn")),
            np.asarray(api.oracle(q_radix, x, mode="snn")))


# ---------------------------------------------------------------------------
# Kernel-level period schedule (the plane-weight extension).
# ---------------------------------------------------------------------------


class TestKernelPeriods:
    def _data(self, m=8, k=16, n=8, bits=3):
        x = jnp.asarray(RNG.integers(0, 1 << bits, (m, k)), jnp.uint8)
        w = jnp.asarray(RNG.integers(-3, 4, (k, n)), jnp.int8)
        return x, w

    @pytest.mark.parametrize("periods", [2, 3])
    def test_periodic_bitserial_matmul_matches_ref(self, periods):
        x, w = self._data()
        got = radix_matmul_pallas(
            jnp.pad(x, ((0, 0), (0, 0))), w, num_steps=3,
            method="bitserial", bm=8, bk=16, bn=8, interpret=True,
            periods=periods)
        want = ref.radix_matmul_ref(x, w, 3, periods=periods)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and the period schedule is value-preserving: == plain radix
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.radix_matmul_ref(x, w,
                                                                      3)))

    def test_periodic_epilogue_matches_ref(self):
        x, w = self._data()
        bias = jnp.asarray(RNG.integers(-20, 20, (1, 8)), jnp.int32)
        mult = jnp.full((1, 8), 0.031, jnp.float32)
        got = radix_matmul_pallas(
            x, w, num_steps=3, method="bitserial", bm=8, bk=16, bn=8,
            interpret=True, periods=2, bias=bias, mult=mult)
        want = ref.radix_matmul_epilogue_ref(x, w, bias, mult, 3, periods=2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_wrapper_threads_spec_schedule(self):
        """ops.radix_matmul given a PhaseEncoding uses its packed bits and
        period-replayed schedule — same ints as the radix identity."""
        spec = api.PhaseEncoding(6, periods=2)       # K = 3
        x, w = self._data(bits=3)
        out = ops.radix_matmul(x, w, None, spec, method="bitserial")
        want = x.astype(jnp.int32) @ w.astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    @pytest.mark.parametrize("method", ["fused", "bitserial"])
    def test_pow2_epilogue_matches_ref(self, method):
        """The kernels' out_grid="pow2" epilogue == the ref oracle's
        grid="pow2" requantizer == TTFSEncoding.requantize, bit-exact."""
        spec = api.TTFSEncoding(3)
        x = jnp.asarray(spec.quantize(
            jnp.asarray(RNG.uniform(0, 1, (8, 16)), jnp.float32)), jnp.uint8)
        w = jnp.asarray(RNG.integers(-3, 4, (16, 8)), jnp.int8)
        bias = jnp.asarray(RNG.integers(-20, 20, (1, 8)), jnp.int32)
        mult = jnp.full((1, 8), 0.043, jnp.float32)
        got = radix_matmul_pallas(
            x, w, num_steps=3, method=method, bm=8, bk=16, bn=8,
            interpret=True, bias=bias, mult=mult, out_grid="pow2")
        want = ref.radix_matmul_epilogue_ref(x, w, bias, mult, 3,
                                             grid="pow2")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        spec_requant = spec.requantize(
            x.astype(jnp.int32) @ w.astype(jnp.int32) + bias, mult)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(spec_requant))
        # every output level lands on the TTFS grid
        grid = set(spec.representable_levels().tolist())
        assert set(np.asarray(got).ravel().tolist()) <= grid
